//! The long-lived services layer: every shared handle the AGNES stack
//! needs to answer work — config, prepared dataset, the sharded
//! [`SsdArray`], both stores, both buffer pools, the feature cache, and
//! the I/O engine — bundled into one [`EngineServices`] value that is
//! `Arc`-shared between the epoch driver ([`super::AgnesRunner`]) and
//! any number of concurrent inference clients ([`super::serve`]).
//!
//! Before this layer existed the runner owned all of these as per-run
//! locals and everything died with the run. Now the runner is a thin
//! epoch driver that borrows the services, and a long-running server
//! can keep the stores, caches, and block remap open across requests.
//!
//! All service methods take `&self`: the underlying handles are either
//! immutable (`Arc<GraphStore>`), internally locked
//! (`SharedBufferPool`, `SharedFeatureCache`), or atomic (store I/O
//! counters, device clocks), so the same `EngineServices` value can be
//! driven from the staged pipeline workers and from serving worker
//! threads at once.

use crate::config::{AgnesConfig, GapBlocks};
use crate::graph::generate::synth_label;
use crate::graph::layout::BlockRemap;
use crate::graph::reorder::{optimize_block_layout, trace_from_log, LayoutPolicy};
use crate::memory::{
    AccessLog, BeladySchedule, CachePolicy, FeatureCacheStats, PoolStats, SharedBufferPool,
    SharedFeatureCache,
};
use crate::metrics::{RunMetrics, StageTimer};
use crate::op::{
    gather_hyperbatch, make_hyperbatches, make_minibatches, sample_hyperbatch, select_targets,
    SampleOutput,
};
use crate::runtime::controller::{
    ControllerAction, ControllerDecision, ControllerInputs, RuntimeController, StoreTrace,
    TraceModel,
};
use crate::storage::block::{FeatureBlockLayout, GraphBlock};
use crate::storage::builder::{apply_block_remap, LayoutMeta};
use crate::storage::device::{
    DeviceStats, SharedArray, SsdArray, TenantStats, TENANT_DEFAULT, TENANT_SERVE,
};
use crate::storage::plan::{BlockBytes, IoPlanner};
use crate::storage::store::{FeatureStore, GraphStore};
use crate::storage::{BlockId, IoEngine};
use crate::Result;
use std::sync::Arc;

use super::compute::MinibatchData;
use super::data::{prepare_dataset, PreparedDataset};

/// The assembled AGNES system (stores + buffers + engine) as a
/// long-lived, shareable service. Stores are `Arc`-shared and the
/// in-memory layer uses shared handles so preparation stages and
/// serving workers can all drive it concurrently.
pub struct EngineServices {
    pub config: AgnesConfig,
    pub dataset: PreparedDataset,
    /// The sharded SSD array: `device.num_ssds` real per-device queues
    /// with stripe-mapped block ownership (one shard — bit-for-bit the
    /// legacy single-queue model — when `num_ssds = 1`).
    pub ssd: SharedArray,
    pub graph_store: Arc<GraphStore>,
    pub feature_store: Arc<FeatureStore>,
    pub graph_pool: SharedBufferPool<GraphBlock>,
    pub feature_pool: SharedBufferPool<BlockBytes>,
    pub feature_cache: SharedFeatureCache,
    pub engine: IoEngine,
    /// The self-tuning runtime controller (`[adaptive]`): adapts pipeline
    /// depth, gap budget, and block layout at epoch boundaries from the
    /// epoch's recorded access traces. Inert when `adaptive.enabled` is
    /// off — the run is then bit-for-bit the static path.
    pub controller: RuntimeController,
}

/// One epoch's recorded pre-residency access logs, drained **once** at
/// the epoch boundary and shared by every consumer (Belady scheduling,
/// the runtime controller) — a second `take_log` would see an empty
/// trace, so consumers must never drain independently.
pub struct EpochLogs {
    pub graph: AccessLog<BlockId>,
    pub feature: AccessLog<BlockId>,
    /// Feature-**cache** accesses are logged per node id (the cache is
    /// node-granular); the controller maps them to feature blocks itself.
    pub cache: AccessLog<u32>,
}

/// The relayout candidate remaps backing an epoch's `Relayout` decisions
/// (kept outside [`ControllerInputs`] — the controller prices them as
/// [`TraceModel`]s; only the applier needs the permutation itself).
pub(crate) struct RelayoutCandidates {
    pub graph: Option<BlockRemap>,
    pub feature: Option<BlockRemap>,
}

impl EngineServices {
    /// Prepare (or reuse) the dataset on disk and assemble the system.
    pub fn open(config: AgnesConfig) -> Result<EngineServices> {
        let dataset = prepare_dataset(&config)?;
        // `num_ssds` real shards, each with its own queue and busy clock,
        // striped over the block space (a single shard is bit-for-bit
        // the legacy one-queue model)
        let spec = config.device.spec();
        let ssd = SsdArray::sharded(spec, config.io.effective_stripe_blocks());
        // Multi-tenant fair sharing: below 1.0, training is guaranteed
        // `tenant.share` of device time and the serving path the
        // remainder, arbitrated by the array's deficit-weighted
        // scheduler. At the default 1.0 nothing is registered and every
        // charge takes the historical unscheduled path bit-for-bit.
        if config.tenant.share < 1.0 {
            let mo = config.tenant.max_outstanding;
            ssd.register_tenant(TENANT_DEFAULT, config.tenant.share, mo);
            ssd.register_tenant(TENANT_SERVE, 1.0 - config.tenant.share, mo);
        }
        let graph_store = Arc::new(GraphStore::open(&dataset.paths, ssd.clone())?);
        let layout = FeatureBlockLayout {
            block_size: config.io.block_size,
            feature_dim: dataset.spec.feature_dim,
        };
        let feature_store = Arc::new(FeatureStore::open(
            &dataset.paths,
            layout,
            dataset.spec.num_nodes,
            ssd.clone(),
        )?);
        let graph_pool = SharedBufferPool::new(config.graph_buffer_blocks());
        let feature_pool = SharedBufferPool::new(config.feature_buffer_blocks());
        let feature_cache = SharedFeatureCache::new(
            config.memory.feature_cache_entries,
            config.memory.feature_cache_threshold,
        );
        if config.cache.policy == CachePolicy::Belady || config.adaptive.enabled {
            // warmup-then-optimal: epoch 0 runs under reactive semantics
            // while every store records its live access trace; each epoch
            // boundary turns the logs into the next epoch's Belady
            // schedules (see `crate::memory::trace`). The adaptive
            // controller consumes the same logs (recording happens at
            // `get()`, before residency, so it never perturbs the run).
            graph_pool.start_recording();
            feature_pool.start_recording();
            feature_cache.start_recording();
        }
        // static gap budgets pass through; the auto knob derives the
        // bridge budget from the device spec (bridge while reading the
        // hole is cheaper than paying another request overhead)
        let gap_blocks = config.io.gap_blocks.resolve(&spec, config.io.block_size);
        let engine = IoEngine::new(config.io.num_threads, config.io.async_depth)
            .with_planner(IoPlanner::new(config.io.max_request_bytes, gap_blocks));
        let controller =
            RuntimeController::new(&config.adaptive, config.train.pipeline_depth as u32);
        Ok(EngineServices {
            config,
            dataset,
            ssd,
            graph_store,
            feature_store,
            graph_pool,
            feature_pool,
            feature_cache,
            engine,
            controller,
        })
    }

    /// The epoch's shuffled target nodes (paper §4.1). Exposed separately
    /// from [`Self::hyperbatches_from_targets`] so the distributed runner
    /// can filter the *same* global target stream down to one worker's
    /// partition while preserving order — with one worker the filtered
    /// stream is the global stream, which is what makes `dist.workers = 1`
    /// bit-identical to the single-machine path.
    pub fn epoch_targets(&self, epoch: usize) -> Vec<u32> {
        let t = &self.config.train;
        select_targets(
            self.dataset.spec.num_nodes,
            t.target_fraction,
            t.seed.wrapping_add(epoch as u64),
        )
    }

    /// Chunk a target stream into minibatches, then hyperbatches (paper
    /// §4.1: minibatch 1000, hyperbatch 1024).
    pub fn hyperbatches_from_targets(&self, targets: &[u32]) -> Vec<Vec<Vec<u32>>> {
        let t = &self.config.train;
        make_hyperbatches(make_minibatches(targets, t.minibatch_size), t.hyperbatch_size)
    }

    /// The epoch's hyperbatches: shuffled targets → minibatches →
    /// hyperbatches (paper §4.1: minibatch 1000, hyperbatch 1024).
    pub fn epoch_hyperbatches(&self, epoch: usize) -> Vec<Vec<Vec<u32>>> {
        self.hyperbatches_from_targets(&self.epoch_targets(epoch))
    }

    /// Data preparation for one hyperbatch: sampling sweep + gathering
    /// sweep. Returns the per-minibatch compute inputs. Takes `&self` so
    /// the pipelined executor can run it on a preparation worker thread.
    /// `index` is the hyperbatch's position in the epoch — the trace
    /// recorder buckets accesses by it and an installed Belady schedule
    /// re-synchronizes its cursor at each boundary.
    pub fn prepare_hyperbatch(
        &self,
        index: usize,
        targets: &[Vec<u32>],
        metrics: &mut RunMetrics,
    ) -> Result<Vec<MinibatchData>> {
        let samples = self.sample_stage(index, targets, metrics)?;
        self.gather_stage(index, targets, &samples, metrics)
    }

    /// The sampling process (S-1..S-3) for one hyperbatch, independently
    /// callable so the three-stage executor can run it on its own worker.
    /// Touches only the graph store / graph buffer; simulated I/O is
    /// attributed through the graph store's per-store charge counter, so
    /// a concurrently running gather stage (feature store) cannot pollute
    /// `sample_io_ns`.
    pub fn sample_stage(
        &self,
        index: usize,
        targets: &[Vec<u32>],
        metrics: &mut RunMetrics,
    ) -> Result<SampleOutput> {
        // open the hyperbatch for the graph buffer's trace recorder /
        // Belady cursor (no-op under the reactive policy)
        self.graph_pool.begin_hyperbatch(index);
        let io_before = self.graph_store.charged_ns();
        let samples;
        {
            let _t = StageTimer::new(&mut metrics.sample_wall_ns);
            samples = sample_hyperbatch(
                &self.graph_store,
                &self.graph_pool,
                &self.engine,
                targets,
                &self.config.train.fanouts,
                self.config.train.seed,
            )?;
        }
        metrics.sample_io_ns += self.graph_store.charged_ns() - io_before;
        metrics.sampled_nodes += samples.total_sampled();
        Ok(samples)
    }

    /// The gathering process (G-1..G-3) + minibatch assembly for one
    /// sampled hyperbatch, independently callable so the three-stage
    /// executor can run it on its own worker. Touches only the feature
    /// store / feature buffer / feature cache (see [`Self::sample_stage`]
    /// for the attribution rationale).
    pub fn gather_stage(
        &self,
        index: usize,
        targets: &[Vec<u32>],
        samples: &SampleOutput,
        metrics: &mut RunMetrics,
    ) -> Result<Vec<MinibatchData>> {
        // open the hyperbatch for the feature buffer's and feature
        // cache's trace recorders / Belady cursors (no-op under reactive)
        self.feature_pool.begin_hyperbatch(index);
        self.feature_cache.begin_hyperbatch(index);
        let fanouts = self.config.train.fanouts.clone();
        let dim = self.dataset.spec.feature_dim;
        let classes = self.dataset.spec.num_classes;
        let node_sets: Vec<Vec<u32>> =
            (0..targets.len()).map(|mb| samples.flat_nodes(mb)).collect();
        let io_before = self.feature_store.charged_ns();
        let gathered;
        {
            let _t = StageTimer::new(&mut metrics.gather_wall_ns);
            gathered = gather_hyperbatch(
                &self.feature_store,
                &self.feature_pool,
                &self.feature_cache,
                &self.engine,
                &node_sets,
            )?;
        }
        metrics.gather_io_ns += self.feature_store.charged_ns() - io_before;
        metrics.gathered_features += gathered.cache_hits + gathered.block_fills;

        // ---- assemble per-minibatch compute inputs (the transfer step
        // happens in the compute backend where the literals are built)
        let mut out = Vec::with_capacity(targets.len());
        let mut gathered_features = gathered.features;
        for (mb, t) in targets.iter().enumerate() {
            let labels =
                t.iter().map(|&v| synth_label(v, classes, dim, self.dataset.spec.seed)).collect();
            out.push(MinibatchData {
                levels: samples.levels[mb].clone(),
                features: std::mem::take(&mut gathered_features[mb]),
                feature_dim: dim,
                labels,
                fanouts: fanouts.clone(),
            });
        }
        metrics.minibatches += targets.len() as u64;
        Ok(out)
    }

    /// End-of-epoch snapshots shared by both executors.
    pub(crate) fn finish_metrics(&self, metrics: &mut RunMetrics) {
        let gp = self.graph_pool.stats();
        let fc = self.feature_cache.stats();
        metrics.graph_hit_ratio = gp.hit_ratio();
        metrics.feature_hit_ratio = fc.hit_ratio();
        metrics.graph_cache_hits = gp.hits;
        metrics.graph_cache_misses = gp.misses;
        metrics.graph_cache_evictions = gp.evictions;
        metrics.feature_cache_hits = fc.hits;
        metrics.feature_cache_misses = fc.misses;
        metrics.feature_cache_evictions = fc.evictions;
        metrics.cache_policy = self.config.cache.policy.name().to_string();
        metrics.device = self.ssd.stats();
        metrics.io_runs = self.graph_store.runs_issued() + self.feature_store.runs_issued();
        metrics.io_run_blocks =
            self.graph_store.run_blocks_read() + self.feature_store.run_blocks_read();
        metrics.effective_gap_blocks = self.engine.effective_gap_blocks();
        metrics.layout_policy = self.config.layout.policy.name().to_string();
        metrics.plan = self.engine.plan_stats();
        let per_shard = self.ssd.per_shard_stats();
        metrics.shards.busy_ns = per_shard.iter().map(|s| s.busy_ns).collect();
        metrics.shards.requests = per_shard.iter().map(|s| s.num_requests).collect();
        metrics.shards.bytes = per_shard.iter().map(|s| s.total_bytes).collect();
        // per-tenant attribution (empty when multi-tenancy is off —
        // unregistered arrays have no tenant table)
        let tenants = self.ssd.tenant_stats();
        if let Some(n) = tenants.iter().map(|(id, _)| *id as usize + 1).max() {
            metrics.tenants = vec![TenantStats::default(); n];
            for (id, st) in &tenants {
                metrics.tenants[*id as usize] = *st;
            }
        }
    }

    /// Drain the epoch's recorded access logs — once; see [`EpochLogs`].
    /// Recording stays on, so the next epoch's trace accumulates afresh.
    pub(crate) fn drain_access_logs(&self) -> EpochLogs {
        EpochLogs {
            graph: self.graph_pool.take_log(),
            feature: self.feature_pool.take_log(),
            cache: self.feature_cache.take_log(),
        }
    }

    /// Warmup-then-optimal epoch boundary: install the Belady schedule
    /// each drained log implies, cursor rewound for the coming epoch
    /// (epoch shuffling makes the traces drift; the per-hyperbatch cursor
    /// resync bounds it).
    pub(crate) fn install_belady_from(&self, logs: &EpochLogs) {
        if !logs.graph.is_empty() {
            self.graph_pool.install_schedule(BeladySchedule::build(&logs.graph));
        }
        if !logs.feature.is_empty() {
            self.feature_pool.install_schedule(BeladySchedule::build(&logs.feature));
        }
        if !logs.cache.is_empty() {
            self.feature_cache.install_schedule(BeladySchedule::build(&logs.cache));
        }
    }

    /// The pipeline depth the next epoch should run at: the configured
    /// `train.pipeline_depth` unless the controller decided (and applied)
    /// a shallower or equal target.
    pub fn effective_pipeline_depth(&self) -> usize {
        self.controller.effective_depth(self.config.train.pipeline_depth as u32) as usize
    }

    /// Map the feature cache's node-granular access log to feature-block
    /// granularity. The cache log is recorded *before* residency is
    /// consulted, so — unlike the feature pool's log, which only sees
    /// cache misses — the block stream is identical across cache policies
    /// and capacities, which the controller's determinism contract needs.
    fn feature_block_log(&self, cache: &AccessLog<u32>) -> AccessLog<BlockId> {
        let fl = self.feature_store.layout;
        AccessLog {
            hyperbatches: cache
                .hyperbatches
                .iter()
                .map(|hb| hb.iter().map(|&v| BlockId(fl.block_of(v))).collect())
                .collect(),
        }
    }

    /// Assemble the controller's epoch observation from the drained logs:
    /// each store's trace priced under its live layout, plus (when online
    /// relayout is enabled) a candidate remap priced against the same
    /// trace. Pure in `(logs, compute_ns)` given fixed stores/config —
    /// the determinism-replay test calls it twice and compares decisions.
    pub(crate) fn controller_inputs(
        &self,
        epoch: u32,
        logs: &EpochLogs,
        compute_ns: u64,
    ) -> Result<(ControllerInputs, RelayoutCandidates)> {
        let spec = self.config.device.spec();
        let map = self.graph_store.stripe_map();
        let bs = self.config.io.block_size;
        let max_req = self.config.io.max_request_bytes;
        let mut stores = Vec::new();
        let mut candidates = RelayoutCandidates { graph: None, feature: None };

        if !logs.graph.is_empty() {
            let remap = self.graph_store.remap();
            let cur = TraceModel::from_log(&logs.graph, &remap, map, bs, max_req);
            let mut st = StoreTrace::new("graph", cur);
            st.file_bytes = self.graph_store.num_blocks() as u64 * bs as u64;
            if self.controller.relayout_enabled() {
                let cand = optimize_block_layout(
                    LayoutPolicy::Hyperbatch,
                    &trace_from_log(&logs.graph),
                    self.graph_store.num_blocks(),
                    map,
                )?;
                if cand != *remap {
                    st.candidate =
                        Some(TraceModel::from_log(&logs.graph, &cand, map, bs, max_req));
                    candidates.graph = Some(cand);
                }
            }
            stores.push(st);
        }

        // oversized feature geometry keeps the identity layout and byte
        // arithmetic; skip modeling it (the optimizer never remaps it)
        let fl = self.feature_store.layout;
        if !logs.cache.is_empty() && fl.feature_bytes() <= fl.block_size {
            let flog = self.feature_block_log(&logs.cache);
            let remap = self.feature_store.remap();
            let cur = TraceModel::from_log(&flog, &remap, map, bs, max_req);
            let mut st = StoreTrace::new("feature", cur);
            st.file_bytes = self.feature_store.num_blocks() as u64 * bs as u64;
            if self.controller.relayout_enabled() {
                let cand = optimize_block_layout(
                    LayoutPolicy::Hyperbatch,
                    &trace_from_log(&flog),
                    self.feature_store.num_blocks(),
                    map,
                )?;
                if cand != *remap {
                    st.candidate = Some(TraceModel::from_log(&flog, &cand, map, bs, max_req));
                    candidates.feature = Some(cand);
                }
            }
            stores.push(st);
        }

        let inputs = ControllerInputs {
            epoch,
            compute_ns,
            current_depth: self.effective_pipeline_depth() as u32,
            current_gap: self.engine.effective_gap_blocks(),
            auto_gap: matches!(self.config.io.gap_blocks, GapBlocks::Auto),
            spec,
            concurrency: self.engine.effective_concurrency(),
            stores,
            tenant_stall_ns: self
                .ssd
                .tenant_stats()
                .iter()
                .find(|(id, _)| *id == TENANT_DEFAULT)
                .map_or(0, |(_, st)| st.stall_ns),
        };
        Ok((inputs, candidates))
    }

    /// One controller step at an epoch boundary: decide from the drained
    /// logs, apply what the controller accepted (gap override on the
    /// engine, relayout on the stores; depth is absorbed by `commit`),
    /// and return the decisions for the epoch's `RunMetrics`.
    pub(crate) fn controller_step(
        &self,
        epoch: u32,
        logs: &EpochLogs,
        compute_ns: u64,
    ) -> Result<Vec<ControllerDecision>> {
        if !self.controller.is_enabled() {
            return Ok(Vec::new());
        }
        let (inputs, candidates) = self.controller_inputs(epoch, logs, compute_ns)?;
        let decisions = self.controller.decide(&inputs);
        for d in &decisions {
            if !d.applied {
                continue;
            }
            match &d.action {
                ControllerAction::Gap { to, .. } => self.engine.set_gap_override(Some(*to)),
                ControllerAction::Relayout { store, .. } => {
                    let cand = match *store {
                        "graph" => candidates.graph.clone(),
                        _ => candidates.feature.clone(),
                    };
                    if let Some(next) = cand {
                        self.apply_relayout(store, next)?;
                    }
                }
                ControllerAction::Depth { .. } => {}
            }
        }
        self.controller.commit(&decisions);
        Ok(decisions)
    }

    /// Rewrite one store's block file so its **full** logical→physical
    /// remap becomes `next`, then persist the sidecar and hot-swap the
    /// store's handle. The on-disk rewrite permutes *physical* positions,
    /// so the streamed permutation is the delta between the live remap
    /// and `next` (block at old physical position `old.physical(l)` must
    /// land at `next.physical(l)`). Atomic temp+rename per file; only
    /// safe at an epoch boundary (no in-flight reads of stale physical
    /// ids — callers hold the boundary).
    fn apply_relayout(&self, store: &str, next: BlockRemap) -> Result<()> {
        let paths = &self.dataset.paths;
        let bs = self.config.io.block_size;
        let mut meta = LayoutMeta::load(paths)?;
        if meta.policy == LayoutPolicy::None {
            // datasets built without the optimizer have no sidecar yet;
            // record which placement family the online permute follows
            meta.policy = LayoutPolicy::Hyperbatch;
        }
        if store == "graph" {
            let old = self.graph_store.remap();
            let delta = delta_remap(&old, &next, self.graph_store.num_blocks())?;
            apply_block_remap(&paths.graph_blocks, bs, &delta)?;
            meta.graph = next;
            meta.write(paths)?;
            self.graph_store.reload_layout(paths)?;
        } else {
            let old = self.feature_store.remap();
            let delta = delta_remap(&old, &next, self.feature_store.num_blocks())?;
            apply_block_remap(&paths.feature_blocks, bs, &delta)?;
            meta.feature = next;
            meta.write(paths)?;
            self.feature_store.reload_layout(paths)?;
        }
        Ok(())
    }

    /// Reset device counters and buffer statistics (between bench phases).
    /// The cache-policy machinery survives: installed Belady schedules are
    /// rewound (not dropped) and partial trace logs discarded, so a
    /// measured pass replays the warm pass's schedule from the top.
    pub fn reset_counters(&self) {
        self.ssd.reset();
        self.graph_store.reset_io_stats();
        self.feature_store.reset_io_stats();
        self.graph_pool.reset_stats();
        self.feature_pool.reset_stats();
        self.graph_pool.restart_trace();
        self.feature_pool.restart_trace();
        self.feature_cache.reset(
            self.config.memory.feature_cache_entries,
            self.config.memory.feature_cache_threshold,
        );
        self.engine.reset_plan_stats();
        // like the Belady schedules, learned adaptive state (depth
        // target, gap override, relayout) survives a counter reset — a
        // measured bench phase is exactly where the warm phase's
        // adaptation should pay off; `controller.reset()` is for callers
        // that really want the static initial state back
        self.controller.reset_log();
    }

    /// One cumulative snapshot of every service counter, taken without
    /// resetting anything — the read-only complement to
    /// [`Self::reset_counters`] that a long-running server uses for
    /// rolling per-window rates (see [`StatsWindow`]).
    pub fn counters(&self) -> ServiceCounters {
        let mut tenants = [TenantStats::default(); COUNTER_TENANTS];
        for (id, st) in self.ssd.tenant_stats() {
            if let Some(slot) = tenants.get_mut(id as usize) {
                *slot = st;
            }
        }
        ServiceCounters {
            graph_pool: self.graph_pool.stats(),
            feature_pool: self.feature_pool.stats(),
            feature_cache: self.feature_cache.stats(),
            device: self.ssd.stats(),
            io_runs: self.graph_store.runs_issued() + self.feature_store.runs_issued(),
            io_run_blocks: self.graph_store.run_blocks_read()
                + self.feature_store.run_blocks_read(),
            tenants,
        }
    }
}

/// The physical-space permutation that rewrites a file laid out by `old`
/// into the layout `next` prescribes: the block at old physical position
/// `old.physical(l)` must land at `next.physical(l)`, expressed in
/// [`apply_block_remap`]'s convention (`to_physical[src] = dst` over
/// file positions). Collapses to the identity (a no-op rewrite) when the
/// two layouts agree.
fn delta_remap(old: &BlockRemap, next: &BlockRemap, num_blocks: u32) -> Result<BlockRemap> {
    let mut to_physical = vec![0u32; num_blocks as usize];
    for l in 0..num_blocks {
        to_physical[old.physical(BlockId(l)).0 as usize] = next.physical(BlockId(l)).0;
    }
    BlockRemap::from_to_physical(to_physical)
}

/// Fixed per-tenant counter slots tracked by [`ServiceCounters`]: slot
/// [`TENANT_DEFAULT`] is training, slot [`TENANT_SERVE`] the inference
/// path. Unregistered tenants (multi-tenancy off) report all zeros.
pub const COUNTER_TENANTS: usize = 2;

/// Cumulative counters across every shared service at one instant.
#[derive(Debug, Clone, Default)]
pub struct ServiceCounters {
    pub graph_pool: PoolStats,
    pub feature_pool: PoolStats,
    pub feature_cache: FeatureCacheStats,
    pub device: DeviceStats,
    pub io_runs: u64,
    pub io_run_blocks: u64,
    /// Per-tenant scheduler counters (see [`COUNTER_TENANTS`]).
    pub tenants: [TenantStats; COUNTER_TENANTS],
}

/// Per-interval counter deltas for one window (see [`StatsWindow`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowStats {
    pub graph_hits: u64,
    pub graph_misses: u64,
    pub feature_hits: u64,
    pub feature_misses: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub device_requests: u64,
    pub device_bytes: u64,
    pub io_runs: u64,
    pub io_run_blocks: u64,
    /// Per-tenant deltas for the window, same slot layout as
    /// [`ServiceCounters::tenants`] (all zeros with multi-tenancy off).
    pub tenants: [TenantStats; COUNTER_TENANTS],
}

impl WindowStats {
    fn rate(hits: u64, misses: u64) -> f64 {
        let total = hits + misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Graph buffer-pool hit rate within this window.
    pub fn graph_hit_rate(&self) -> f64 {
        Self::rate(self.graph_hits, self.graph_misses)
    }

    /// Feature buffer-pool hit rate within this window.
    pub fn feature_hit_rate(&self) -> f64 {
        Self::rate(self.feature_hits, self.feature_misses)
    }

    /// Feature cache hit rate within this window.
    pub fn cache_hit_rate(&self) -> f64 {
        Self::rate(self.cache_hits, self.cache_misses)
    }
}

/// Rolling per-window view over the cumulative service counters.
///
/// `reset_counters` is epoch-scoped and destructive (it wipes device
/// clocks and partial trace logs), so a long-running server must never
/// call it between windows — doing so would also rewind installed Belady
/// schedules mid-trace. Instead, `StatsWindow` remembers the last
/// cumulative snapshot and reports saturating deltas, leaving every
/// schedule, trace recorder, and cumulative counter untouched.
pub struct StatsWindow {
    last: ServiceCounters,
}

impl StatsWindow {
    /// Open a window at the services' current counter values.
    pub fn new(services: &EngineServices) -> StatsWindow {
        StatsWindow { last: services.counters() }
    }

    /// Close the current window and open the next: returns the counter
    /// deltas accumulated since the previous `roll` (or `new`).
    pub fn roll(&mut self, services: &EngineServices) -> WindowStats {
        let now = services.counters();
        let mut tenants = [TenantStats::default(); COUNTER_TENANTS];
        for (i, slot) in tenants.iter_mut().enumerate() {
            *slot = TenantStats {
                bytes: now.tenants[i].bytes.saturating_sub(self.last.tenants[i].bytes),
                requests: now.tenants[i].requests.saturating_sub(self.last.tenants[i].requests),
                busy_ns: now.tenants[i].busy_ns.saturating_sub(self.last.tenants[i].busy_ns),
                stall_ns: now.tenants[i].stall_ns.saturating_sub(self.last.tenants[i].stall_ns),
            };
        }
        let w = WindowStats {
            graph_hits: now.graph_pool.hits.saturating_sub(self.last.graph_pool.hits),
            graph_misses: now.graph_pool.misses.saturating_sub(self.last.graph_pool.misses),
            feature_hits: now.feature_pool.hits.saturating_sub(self.last.feature_pool.hits),
            feature_misses: now.feature_pool.misses.saturating_sub(self.last.feature_pool.misses),
            cache_hits: now.feature_cache.hits.saturating_sub(self.last.feature_cache.hits),
            cache_misses: now.feature_cache.misses.saturating_sub(self.last.feature_cache.misses),
            device_requests: now.device.num_requests.saturating_sub(self.last.device.num_requests),
            device_bytes: now.device.total_bytes.saturating_sub(self.last.device.total_bytes),
            io_runs: now.io_runs.saturating_sub(self.last.io_runs),
            io_run_blocks: now.io_run_blocks.saturating_sub(self.last.io_run_blocks),
            tenants,
        };
        self.last = now;
        w
    }
}

#[cfg(test)]
mod tests {
    use super::super::{AgnesRunner, NullCompute};
    use super::*;

    fn services() -> (EngineServices, crate::util::TempDir) {
        let tmp = crate::util::TempDir::new().unwrap();
        let mut c = AgnesConfig::tiny();
        c.dataset.data_dir = tmp.path().to_string_lossy().into_owned();
        (EngineServices::open(c).unwrap(), tmp)
    }

    #[test]
    fn runner_shares_services() {
        let (s, _tmp) = services();
        let mut r = AgnesRunner::from_services(Arc::new(s));
        let shared = r.services();
        let res = r.run_epoch(0, &mut NullCompute).unwrap();
        assert!(res.metrics.minibatches > 0);
        // the epoch drove the *shared* services, not a private copy
        assert!(shared.counters().device.num_requests > 0);
    }

    #[test]
    fn stats_window_reports_deltas_without_resetting() {
        let (s, _tmp) = services();
        let s = Arc::new(s);
        let mut r = AgnesRunner::from_services(s.clone());
        let mut window = StatsWindow::new(&s);

        r.run_epoch(0, &mut NullCompute).unwrap();
        let before = s.counters();
        let w0 = window.roll(&s);
        // rolling a window is read-only: cumulative counters unchanged
        let after = s.counters();
        assert_eq!(before.device.num_requests, after.device.num_requests);
        assert_eq!(before.graph_pool, after.graph_pool);
        assert!(w0.device_requests > 0);
        assert!(w0.graph_hits + w0.graph_misses > 0);
        assert!((0.0..=1.0).contains(&w0.graph_hit_rate()));

        r.run_epoch(1, &mut NullCompute).unwrap();
        let w1 = window.roll(&s);
        // the second window covers only epoch 1: the two windows sum to
        // the cumulative totals
        let total = s.counters();
        assert_eq!(w0.device_requests + w1.device_requests, total.device.num_requests);
        assert_eq!(
            w0.cache_hits + w0.cache_misses + w1.cache_hits + w1.cache_misses,
            total.feature_cache.hits + total.feature_cache.misses
        );
        // an empty window is all zeros
        let w2 = window.roll(&s);
        assert_eq!(w2.device_requests, 0);
        assert_eq!(w2.graph_hits + w2.graph_misses, 0);
        assert_eq!(w2.graph_hit_rate(), 0.0);
    }

    #[test]
    fn stats_windows_attribute_each_tenant_separately() {
        let tmp = crate::util::TempDir::new().unwrap();
        let mut c = AgnesConfig::tiny();
        c.dataset.data_dir = tmp.path().to_string_lossy().into_owned();
        c.tenant.share = 0.6; // registers training @0.6 and serving @0.4
        let s = Arc::new(EngineServices::open(c).unwrap());
        let mut r = AgnesRunner::from_services(s.clone());
        let mut window = StatsWindow::new(&s);

        // a training epoch is charged to the training tenant only
        r.run_epoch(0, &mut NullCompute).unwrap();
        let w0 = window.roll(&s);
        assert!(w0.tenants[TENANT_DEFAULT as usize].requests > 0);
        assert!(w0.tenants[TENANT_DEFAULT as usize].bytes > 0);
        assert_eq!(w0.tenants[TENANT_SERVE as usize].requests, 0);

        // serving-tenant traffic lands in the other slot only — and the
        // roll is non-destructive, so the cumulative totals equal the
        // window sums per tenant
        let per_shard: Vec<Vec<u64>> =
            (0..s.ssd.num_shards()).map(|_| vec![1u64 << 20]).collect();
        let batch = crate::storage::device::IoBatch::shard_sizes(&per_shard)
            .for_tenant(TENANT_SERVE);
        s.ssd.submit(&batch, 4);
        let w1 = window.roll(&s);
        assert_eq!(w1.tenants[TENANT_DEFAULT as usize].requests, 0);
        assert!(w1.tenants[TENANT_SERVE as usize].requests > 0);
        let total = s.counters();
        for t in [TENANT_DEFAULT as usize, TENANT_SERVE as usize] {
            assert_eq!(
                w0.tenants[t].requests + w1.tenants[t].requests,
                total.tenants[t].requests
            );
        }
    }
}
