//! Computation-stage backends.
//!
//! The coordinator hands each prepared minibatch to a [`ComputeBackend`]:
//! * [`NullCompute`] — data-preparation-only runs (the paper's Fig 4, 9,
//!   10, 11 measure the preparation stage);
//! * [`ModeledCompute`] — charges a fixed per-minibatch compute cost
//!   calibrated from the real executable, so full-figure benches don't pay
//!   the wall-clock of thousands of XLA executions;
//! * `runtime::XlaCompute` — the real thing: the AOT-compiled JAX/Pallas
//!   HLO executed on the PJRT CPU client (see [`crate::runtime`]).

use crate::Result;

/// One prepared minibatch, ready for the accelerator.
#[derive(Debug, Clone)]
pub struct MinibatchData {
    /// Node arrays per tree level (level 0 = targets).
    pub levels: Vec<Vec<u32>>,
    /// Contiguous features of all levels' nodes, in level order
    /// (`sum(level sizes) * feature_dim`).
    pub features: Vec<f32>,
    pub feature_dim: usize,
    /// Labels of the level-0 targets.
    pub labels: Vec<u32>,
    /// Sampling fanouts (fixed shapes).
    pub fanouts: Vec<usize>,
}

impl MinibatchData {
    /// Total node slots across levels.
    pub fn total_nodes(&self) -> usize {
        self.levels.iter().map(Vec::len).sum()
    }
}

/// Result of one training step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepResult {
    pub loss: f32,
    /// Correct predictions among the targets (for accuracy curves).
    pub correct: u32,
    pub total: u32,
}

/// The computation stage (paper Figure 1 steps (iv)–(v)).
pub trait ComputeBackend {
    fn train_step(&mut self, mb: &MinibatchData) -> Result<StepResult>;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str {
        "compute"
    }

    /// Cumulative *simulated* compute nanoseconds (0 for real backends).
    /// The epoch executor samples this around each hyperbatch so modeled
    /// compute participates in the pipeline span accounting.
    fn simulated_ns(&self) -> u64 {
        0
    }
}

/// No computation (prep-only benches).
#[derive(Debug, Default)]
pub struct NullCompute;

impl ComputeBackend for NullCompute {
    fn train_step(&mut self, mb: &MinibatchData) -> Result<StepResult> {
        Ok(StepResult { loss: 0.0, correct: 0, total: mb.labels.len() as u32 })
    }

    fn name(&self) -> &'static str {
        "null"
    }
}

/// Fixed-cost compute model: spins for `ns_per_step` simulated nanoseconds
/// (accounted, not slept) so figure benches include a computation stage of
/// realistic relative size without executing XLA thousands of times.
#[derive(Debug)]
pub struct ModeledCompute {
    pub ns_per_step: u64,
    /// Accumulated simulated compute nanoseconds.
    pub simulated_ns: u64,
}

impl ModeledCompute {
    pub fn new(ns_per_step: u64) -> ModeledCompute {
        ModeledCompute { ns_per_step, simulated_ns: 0 }
    }
}

impl ComputeBackend for ModeledCompute {
    fn train_step(&mut self, mb: &MinibatchData) -> Result<StepResult> {
        self.simulated_ns += self.ns_per_step;
        Ok(StepResult { loss: 0.0, correct: 0, total: mb.labels.len() as u32 })
    }

    fn name(&self) -> &'static str {
        "modeled"
    }

    fn simulated_ns(&self) -> u64 {
        self.simulated_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mb() -> MinibatchData {
        MinibatchData {
            levels: vec![vec![1, 2], vec![3, 4, 5, 6]],
            features: vec![0.0; 6 * 4],
            feature_dim: 4,
            labels: vec![0, 1],
            fanouts: vec![2],
        }
    }

    #[test]
    fn null_counts_targets() {
        let r = NullCompute.train_step(&mb()).unwrap();
        assert_eq!(r.total, 2);
    }

    #[test]
    fn modeled_accumulates() {
        let mut c = ModeledCompute::new(1000);
        c.train_step(&mb()).unwrap();
        c.train_step(&mb()).unwrap();
        assert_eq!(c.simulated_ns, 2000);
    }

    #[test]
    fn total_nodes_sums_levels() {
        assert_eq!(mb().total_nodes(), 6);
    }
}
