//! Online node-inference serving on top of the shared services layer.
//!
//! Training amortizes storage latency across an epoch; serving cares
//! about *per-request* latency. [`InferenceServer`] wraps one
//! [`EngineServices`] (stores, buffer pools, feature cache, block remap
//! all stay warm across requests) and answers concurrent requests, each
//! a deterministic seeded sample → coalesced gather → forward pass:
//!
//! * **Determinism** — sampling is driven by the request's own seed
//!   through the per-slot RNG, and gather results are
//!   position-addressed, so a request's response is bit-identical no
//!   matter how many other requests run concurrently or what the cache
//!   holds (the serving tests assert digest equality against a
//!   sequential replay).
//! * **Bounded admission** — at most `serve.max_inflight` requests may
//!   be in flight; the next one is rejected with the typed
//!   [`ServeError::Overloaded`] instead of queueing without bound.
//!   Rejected requests count in `ServeMetrics::rejected` but never
//!   touch the latency histogram.
//! * **Latency accounting** — every completed request records its
//!   sample/gather/compute breakdown and total latency into a log2
//!   [`LatencyHistogram`]; [`InferenceServer::metrics`] reports
//!   p50/p95/p99 and the per-stage sums through [`RunMetrics`].
//! * **Hot reload** — [`InferenceServer::reload`] re-validates a
//!   whitelisted knob through the config's own check functions and swaps
//!   the knob bundle atomically between requests: in-flight work keeps
//!   the `Arc` snapshot it started with, so nothing is dropped.
//!
//! The epoch-scoped trace machinery (`begin_hyperbatch`, Belady
//! cursors) is deliberately *not* driven here: concurrent requests have
//! no global hyperbatch order to synchronize cursors against. Serving
//! therefore works on any policy, but `cache.policy = "reactive"` is
//! the intended serving configuration; under `belady` the recorders
//! keep logging and the logs are simply never turned into schedules.

use super::compute::ComputeBackend;
use super::compute::MinibatchData;
use super::services::EngineServices;
use crate::config::AgnesConfig;
use crate::graph::generate::synth_label;
use crate::metrics::{LatencyHistogram, RunMetrics};
use crate::op::{gather_hyperbatch, sample_hyperbatch};
use crate::storage::device::TENANT_SERVE;
use crate::storage::plan::IoPlanner;
use crate::storage::IoEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// One node-inference request: compute predictions for `targets` using
/// the deterministic sampling stream of `seed`.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: u64,
    /// Target nodes to infer (one serving minibatch).
    pub targets: Vec<u32>,
    /// Sampling seed: the same `(targets, seed)` pair always produces a
    /// bit-identical response.
    pub seed: u64,
}

/// Per-stage wall-clock breakdown of one served request.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageBreakdown {
    pub sample_ns: u64,
    pub gather_ns: u64,
    pub compute_ns: u64,
    pub total_ns: u64,
}

/// The answer to one [`InferenceRequest`].
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub loss: f32,
    pub correct: u32,
    pub total: u32,
    /// Gathered node slots (all levels, incl. duplicates).
    pub nodes: u64,
    /// FNV-1a over the gathered feature bits — the determinism witness
    /// the serving tests compare across concurrent and sequential runs.
    pub features_digest: u64,
    pub timing: StageBreakdown,
}

/// Typed serving failure.
#[derive(Debug)]
pub enum ServeError {
    /// Admission control: the server already has `max_inflight` requests
    /// in flight. Back off and retry; nothing was executed or recorded
    /// in the latency histogram.
    Overloaded { inflight: usize, max_inflight: usize },
    /// The request was admitted but a pipeline stage failed.
    Failed(anyhow::Error),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { inflight, max_inflight } => write!(
                f,
                "server overloaded: {inflight} requests in flight (serve.max_inflight = \
                 {max_inflight})"
            ),
            ServeError::Failed(e) => write!(f, "inference failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// The hot-reloadable knob bundle. Snapshotted (`Arc`) by every request
/// at admission: a concurrent [`InferenceServer::reload`] swaps the
/// server's bundle for new requests while in-flight ones finish on the
/// snapshot they started with.
pub struct ServeKnobs {
    pub config: AgnesConfig,
    /// The I/O engine carries the planner knobs (`io.max_request_bytes`,
    /// `io.gap_blocks`); an `io.*` reload rebuilds it, anything else
    /// shares the existing one.
    pub engine: Arc<IoEngine>,
}

/// Cumulative serving counters (under one lock with the histogram so a
/// snapshot is consistent).
#[derive(Default)]
struct ServeStats {
    requests: u64,
    rejected: u64,
    sample_ns: u64,
    gather_ns: u64,
    compute_ns: u64,
    latency: LatencyHistogram,
}

/// A long-running inference server over shared [`EngineServices`].
///
/// All methods take `&self`; the server is driven from many worker
/// threads at once (see the `serve` subcommand in `main.rs`).
pub struct InferenceServer {
    services: Arc<EngineServices>,
    knobs: Mutex<Arc<ServeKnobs>>,
    inflight: AtomicUsize,
    stats: Mutex<ServeStats>,
}

/// An admitted in-flight slot, released on drop. Obtained from
/// [`InferenceServer::try_admit`]; holds an `Arc` to the server so the
/// token can cross a work-queue channel to whichever worker executes it.
pub struct AdmitToken {
    server: Arc<InferenceServer>,
}

impl AdmitToken {
    /// Execute `req` on the admitted slot and release it.
    pub fn run(
        self,
        req: &InferenceRequest,
        compute: &mut dyn ComputeBackend,
    ) -> Result<InferenceResponse, ServeError> {
        self.server.execute(req, compute)
        // Drop releases the slot
    }
}

impl Drop for AdmitToken {
    fn drop(&mut self) {
        self.server.release_slot();
    }
}

/// Borrow-scoped variant of [`AdmitToken`] used by
/// [`InferenceServer::handle_request`].
struct SlotGuard<'a>(&'a InferenceServer);

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.0.release_slot();
    }
}

impl InferenceServer {
    /// Wrap the shared services. The initial knob bundle mirrors
    /// `services.config`; the serving engine is built fresh so `io.*`
    /// reloads can swap it without touching the training engine.
    pub fn new(services: Arc<EngineServices>) -> InferenceServer {
        let config = services.config.clone();
        let engine = Arc::new(build_engine(&config));
        InferenceServer {
            services,
            knobs: Mutex::new(Arc::new(ServeKnobs { config, engine })),
            inflight: AtomicUsize::new(0),
            stats: Mutex::new(ServeStats::default()),
        }
    }

    /// The current knob bundle snapshot.
    pub fn knobs(&self) -> Arc<ServeKnobs> {
        Arc::clone(&self.lock_knobs())
    }

    /// Requests currently in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// The shared services this server answers from.
    pub fn services(&self) -> Arc<EngineServices> {
        Arc::clone(&self.services)
    }

    /// Admit-and-execute in one call (the caller's thread does the
    /// work). Rejects with [`ServeError::Overloaded`] beyond
    /// `serve.max_inflight`.
    pub fn handle_request(
        &self,
        req: &InferenceRequest,
        compute: &mut dyn ComputeBackend,
    ) -> Result<InferenceResponse, ServeError> {
        self.admit_slot()?;
        let _guard = SlotGuard(self);
        self.execute(req, compute)
    }

    /// Admission for queued execution: reserve an in-flight slot now (so
    /// backpressure applies at enqueue time), execute later on any
    /// worker via [`AdmitToken::run`]. Dropping the token releases the
    /// slot.
    pub fn try_admit(self: &Arc<Self>) -> Result<AdmitToken, ServeError> {
        self.admit_slot()?;
        Ok(AdmitToken { server: Arc::clone(self) })
    }

    /// Cumulative serving metrics: request/reject counts, latency
    /// percentiles from the log2 histogram (inclusive bucket upper
    /// bounds, so within 2x and never optimistic), and the per-stage
    /// nanosecond sums.
    pub fn metrics(&self) -> RunMetrics {
        let st = self.lock_stats();
        RunMetrics {
            serve: crate::metrics::ServeMetrics {
                requests: st.requests,
                rejected: st.rejected,
                p50_ns: st.latency.percentile(50.0),
                p95_ns: st.latency.percentile(95.0),
                p99_ns: st.latency.percentile(99.0),
                sample_ns: st.sample_ns,
                gather_ns: st.gather_ns,
                compute_ns: st.compute_ns,
            },
            ..RunMetrics::default()
        }
    }

    /// Latencies recorded so far (== completed requests; rejected ones
    /// never record).
    pub fn recorded_latencies(&self) -> u64 {
        self.lock_stats().latency.count()
    }

    /// Hot-reload one `section.key` knob. Only knobs that are safe to
    /// swap between requests are accepted:
    ///
    /// * `io.max_request_bytes`, `io.gap_blocks` — rebuild the serving
    ///   I/O engine with a re-validated planner
    /// * `memory.feature_cache_entries`, `memory.feature_cache_threshold`
    ///   — resize the shared feature cache (admission counts reset;
    ///   correctness is residency-independent)
    /// * `serve.max_inflight` — admission bound for *new* requests
    /// * `adaptive.enabled`, `adaptive.frozen`, `adaptive.relayout`,
    ///   `adaptive.min_gain` — drive the live runtime controller
    ///   (enabling also turns trace recording on; freezing makes it
    ///   observe-only from the next epoch boundary)
    ///
    /// The value goes through [`AgnesConfig::apply_kv`] (the same typed
    /// parser `set()` uses) and the full [`AgnesConfig::validate`], so a
    /// reload can never install a config the CLI would have rejected at
    /// startup. On success the bundle is swapped atomically; in-flight
    /// requests finish on their admission-time snapshot.
    pub fn reload(&self, key: &str, value: &str) -> Result<(), String> {
        const RELOADABLE: &[(&str, &str)] = &[
            ("io", "max_request_bytes"),
            ("io", "gap_blocks"),
            ("memory", "feature_cache_entries"),
            ("memory", "feature_cache_threshold"),
            ("serve", "max_inflight"),
            ("adaptive", "enabled"),
            ("adaptive", "frozen"),
            ("adaptive", "relayout"),
            ("adaptive", "min_gain"),
        ];
        let (section, k) = key
            .split_once('.')
            .ok_or_else(|| format!("expected section.key, got {key:?}"))?;
        if !RELOADABLE.contains(&(section, k)) {
            return Err(format!(
                "{key} is not hot-reloadable (reloadable: {})",
                RELOADABLE
                    .iter()
                    .map(|(s, k)| format!("{s}.{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        let current = self.knobs();
        let mut config = current.config.clone();
        config.apply_kv(section, k, value)?;
        config.validate().map_err(|e| e.to_string())?;
        let engine = if section == "io" {
            Arc::new(build_engine(&config))
        } else {
            Arc::clone(&current.engine)
        };
        if section == "memory" {
            self.services.feature_cache.reset(
                config.memory.feature_cache_entries,
                config.memory.feature_cache_threshold,
            );
        }
        if section == "adaptive" {
            // drive the *live* controller shared with any training
            // driver on these services; decisions change from the next
            // epoch boundary on
            let a = &config.adaptive;
            let ctl = &self.services.controller;
            ctl.set_frozen(a.frozen);
            ctl.set_relayout(a.relayout);
            ctl.set_min_gain(a.min_gain);
            if a.enabled && !ctl.is_enabled() {
                // enabling at runtime must also turn trace recording on,
                // or the controller would observe empty logs forever
                self.services.graph_pool.start_recording();
                self.services.feature_pool.start_recording();
                self.services.feature_cache.start_recording();
            }
            ctl.set_enabled(a.enabled);
        }
        *self.lock_knobs() = Arc::new(ServeKnobs { config, engine });
        Ok(())
    }

    fn lock_knobs(&self) -> MutexGuard<'_, Arc<ServeKnobs>> {
        self.knobs.lock().expect("serve knobs poisoned")
    }

    fn lock_stats(&self) -> MutexGuard<'_, ServeStats> {
        self.stats.lock().expect("serve stats poisoned")
    }

    fn admit_slot(&self) -> Result<(), ServeError> {
        let max = self.knobs().config.serve.max_inflight;
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        if prev >= max {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.lock_stats().rejected += 1;
            return Err(ServeError::Overloaded { inflight: prev, max_inflight: max });
        }
        Ok(())
    }

    fn release_slot(&self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    /// The admitted request body: seeded sample → gather → forward pass,
    /// timed per stage. Runs entirely on shared `&self` handles, so any
    /// number of workers execute concurrently.
    fn execute(
        &self,
        req: &InferenceRequest,
        compute: &mut dyn ComputeBackend,
    ) -> Result<InferenceResponse, ServeError> {
        let knobs = self.knobs();
        let s = &self.services;
        let start = Instant::now();

        let samples = sample_hyperbatch(
            &s.graph_store,
            &s.graph_pool,
            &knobs.engine,
            std::slice::from_ref(&req.targets),
            &knobs.config.train.fanouts,
            req.seed,
        )
        .map_err(ServeError::Failed)?;
        let sample_ns = start.elapsed().as_nanos() as u64;

        let gather_start = Instant::now();
        let node_sets = vec![samples.flat_nodes(0)];
        let nodes = node_sets[0].len() as u64;
        let gathered = gather_hyperbatch(
            &s.feature_store,
            &s.feature_pool,
            &s.feature_cache,
            &knobs.engine,
            &node_sets,
        )
        .map_err(ServeError::Failed)?;
        let gather_ns = gather_start.elapsed().as_nanos() as u64;

        let compute_start = Instant::now();
        let dim = s.dataset.spec.feature_dim;
        let classes = s.dataset.spec.num_classes;
        let labels = req
            .targets
            .iter()
            .map(|&v| synth_label(v, classes, dim, s.dataset.spec.seed))
            .collect();
        let mut levels_iter = samples.levels.into_iter();
        let mut features_iter = gathered.features.into_iter();
        let mb = MinibatchData {
            levels: levels_iter.next().expect("one minibatch sampled"),
            features: features_iter.next().expect("one minibatch gathered"),
            feature_dim: dim,
            labels,
            fanouts: knobs.config.train.fanouts.clone(),
        };
        let step = compute.train_step(&mb).map_err(ServeError::Failed)?;
        let compute_ns = compute_start.elapsed().as_nanos() as u64;

        let timing = StageBreakdown {
            sample_ns,
            gather_ns,
            compute_ns,
            total_ns: start.elapsed().as_nanos() as u64,
        };
        {
            let mut st = self.lock_stats();
            st.requests += 1;
            st.sample_ns += timing.sample_ns;
            st.gather_ns += timing.gather_ns;
            st.compute_ns += timing.compute_ns;
            st.latency.record(timing.total_ns);
        }
        Ok(InferenceResponse {
            id: req.id,
            loss: step.loss,
            correct: step.correct,
            total: step.total,
            nodes,
            features_digest: features_digest(&mb.features),
            timing,
        })
    }
}

/// Build the serving I/O engine from a validated config (same recipe as
/// [`EngineServices::open`]), tagged with the serving tenant so its
/// device charges are attributed — and, under `tenant.share < 1.0`,
/// fair-share scheduled — separately from training. With multi-tenancy
/// off the tag is inert: an unregistered tenant takes the historical
/// unscheduled path bit-for-bit.
fn build_engine(config: &AgnesConfig) -> IoEngine {
    let spec = config.device.spec();
    let gap = config.io.gap_blocks.resolve(&spec, config.io.block_size);
    IoEngine::new(config.io.num_threads, config.io.async_depth)
        .with_planner(IoPlanner::new(config.io.max_request_bytes, gap))
        .with_tenant(TENANT_SERVE)
}

/// FNV-1a over the gathered feature bits: cheap, order-sensitive, and
/// exact — two responses match iff every f32 matches bit-for-bit.
fn features_digest(features: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &f in features {
        for b in f.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::super::NullCompute;
    use super::*;
    use crate::coordinator::compute::StepResult;
    use std::sync::mpsc;

    fn server_with(
        mutate: impl FnOnce(&mut AgnesConfig),
    ) -> (Arc<InferenceServer>, crate::util::TempDir) {
        let tmp = crate::util::TempDir::new().unwrap();
        let mut c = AgnesConfig::tiny();
        c.dataset.data_dir = tmp.path().to_string_lossy().into_owned();
        mutate(&mut c);
        let services = Arc::new(EngineServices::open(c).unwrap());
        (Arc::new(InferenceServer::new(services)), tmp)
    }

    /// Deterministic request batch over the tiny dataset.
    fn requests(server: &InferenceServer, n: usize, batch: usize) -> Vec<InferenceRequest> {
        let num_nodes = server.services().dataset.spec.num_nodes as u64;
        let mut state = 0x243f_6a88_85a3_08d3u64;
        (0..n)
            .map(|i| {
                let targets = (0..batch)
                    .map(|_| {
                        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                        (state % num_nodes) as u32
                    })
                    .collect();
                InferenceRequest { id: i as u64, targets, seed: 1000 + i as u64 }
            })
            .collect()
    }

    #[test]
    fn concurrent_requests_bit_identical_to_sequential() {
        let (server, _tmp) = server_with(|_| {});
        let reqs = requests(&server, 12, 8);

        // sequential reference digests
        let expected: Vec<(u64, u64)> = reqs
            .iter()
            .map(|r| {
                let resp = server.handle_request(r, &mut NullCompute).unwrap();
                assert_eq!(resp.id, r.id);
                assert!(resp.nodes > 0);
                (resp.features_digest, resp.nodes)
            })
            .collect();

        // 4 concurrent clients over disjoint quarters of the same batch
        let mut got: Vec<(u64, (u64, u64))> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|client| {
                    let server = &server;
                    let reqs = &reqs;
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        for r in reqs.iter().skip(client).step_by(4) {
                            let resp = server.handle_request(r, &mut NullCompute).unwrap();
                            out.push((r.id, (resp.features_digest, resp.nodes)));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        got.sort_unstable_by_key(|&(id, _)| id);
        assert_eq!(got.len(), expected.len());
        for (id, digest) in got {
            assert_eq!(
                digest, expected[id as usize],
                "request {id}: concurrent response must be bit-identical to sequential"
            );
        }
        // all 24 requests (12 sequential + 12 concurrent) completed
        let m = server.metrics();
        assert_eq!(m.serve.requests, 24);
        assert_eq!(m.serve.rejected, 0);
        assert!(m.serve.p99_ns >= m.serve.p50_ns);
        assert!(m.serve.p50_ns > 0);
        assert!(m.serve.sample_ns > 0 && m.serve.gather_ns > 0);
    }

    /// A compute backend that parks inside `train_step` until released,
    /// holding its admission slot occupied.
    struct GateCompute {
        entered: mpsc::Sender<()>,
        release: Arc<Mutex<mpsc::Receiver<()>>>,
    }

    impl ComputeBackend for GateCompute {
        fn train_step(&mut self, mb: &MinibatchData) -> crate::Result<StepResult> {
            self.entered.send(()).unwrap();
            self.release.lock().unwrap().recv().unwrap();
            Ok(StepResult { loss: 0.0, correct: 0, total: mb.labels.len() as u32 })
        }
    }

    #[test]
    fn admission_rejects_above_max_inflight() {
        let (server, _tmp) = server_with(|c| c.serve.max_inflight = 2);
        let reqs = requests(&server, 3, 4);
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        let release_rx = Arc::new(Mutex::new(release_rx));

        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..2)
                .map(|i| {
                    let server = &server;
                    let req = &reqs[i];
                    let mut gate = GateCompute {
                        entered: entered_tx.clone(),
                        release: Arc::clone(&release_rx),
                    };
                    scope.spawn(move || server.handle_request(req, &mut gate))
                })
                .collect();
            // both requests are parked inside compute, slots held
            entered_rx.recv().unwrap();
            entered_rx.recv().unwrap();
            assert_eq!(server.inflight(), 2);

            // the (max_inflight + 1)-th request is rejected, typed
            let err = server.handle_request(&reqs[2], &mut NullCompute).unwrap_err();
            match err {
                ServeError::Overloaded { inflight, max_inflight } => {
                    assert_eq!(inflight, 2);
                    assert_eq!(max_inflight, 2);
                }
                other => panic!("expected Overloaded, got {other}"),
            }

            release_tx.send(()).unwrap();
            release_tx.send(()).unwrap();
            for w in workers {
                w.join().unwrap().unwrap();
            }
        });

        assert_eq!(server.inflight(), 0, "slots released after completion");
        let m = server.metrics();
        assert_eq!(m.serve.requests, 2);
        assert_eq!(m.serve.rejected, 1);
        // the rejection left no trace in the latency accounting
        assert_eq!(server.recorded_latencies(), 2);
    }

    #[test]
    fn hot_reload_mid_burst_drops_nothing() {
        let (server, _tmp) = server_with(|_| {});
        let reqs = requests(&server, 12, 6);
        let expected: Vec<u64> = reqs
            .iter()
            .map(|r| server.handle_request(r, &mut NullCompute).unwrap().features_digest)
            .collect();

        // 4 clients re-run the burst while the main thread swaps knobs
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|client| {
                    let server = &server;
                    let reqs = &reqs;
                    let expected = &expected;
                    scope.spawn(move || {
                        for r in reqs.iter().skip(client).step_by(4) {
                            let resp = server.handle_request(r, &mut NullCompute).unwrap();
                            assert_eq!(
                                resp.features_digest, expected[r.id as usize],
                                "request {} served across a reload must stay bit-identical",
                                r.id
                            );
                        }
                    })
                })
                .collect();
            // reloads race the burst: cache resize, then planner swap
            server.reload("memory.feature_cache_entries", "32").unwrap();
            server.reload("io.gap_blocks", "3").unwrap();
            for h in handles {
                h.join().unwrap();
            }
        });

        // the swapped bundle is what new requests see
        let knobs = server.knobs();
        assert_eq!(knobs.config.memory.feature_cache_entries, 32);
        assert_eq!(knobs.engine.planner.gap_blocks, 3, "io reload rebuilt the engine");
        assert_eq!(knobs.engine.tenant(), TENANT_SERVE, "rebuilt engine keeps the serving tenant");

        // every request completed exactly once per pass
        let m = server.metrics();
        assert_eq!(m.serve.requests, 24);
        assert_eq!(m.serve.rejected, 0);

        // rejected reloads: out-of-range value, non-whitelisted keys
        let err = server.reload("io.gap_blocks", "9999").unwrap_err();
        assert!(err.contains("io.gap_blocks"), "{err}");
        let err = server.reload("train.seed", "2").unwrap_err();
        assert!(err.contains("not hot-reloadable"), "{err}");
        let err = server.reload("io.max_request_bytes", "0").unwrap_err();
        assert!(err.contains("io.max_request_bytes"), "{err}");
        let err = server.reload("nonsense", "1").unwrap_err();
        assert!(err.contains("section.key"), "{err}");
        // failed reloads left the good bundle in place
        assert_eq!(server.knobs().engine.planner.gap_blocks, 3);
    }

    #[test]
    fn adaptive_keys_hot_reload_onto_live_controller() {
        let (server, _tmp) = server_with(|_| {});
        let services = server.services();
        let ctl = &services.controller;
        assert!(!ctl.is_enabled(), "tiny config starts with the controller off");

        // enable + tune: the live controller (not just the knob bundle)
        // must reflect every accepted reload
        server.reload("adaptive.enabled", "true").unwrap();
        server.reload("adaptive.frozen", "true").unwrap();
        server.reload("adaptive.relayout", "true").unwrap();
        server.reload("adaptive.min_gain", "0.25").unwrap();
        assert!(ctl.is_enabled() && ctl.is_frozen() && ctl.relayout_enabled());
        assert_eq!(ctl.min_gain(), 0.25);
        assert!(server.knobs().config.adaptive.enabled, "knob bundle tracks the reload");
        // enabling turned recording on, so a future epoch boundary sees
        // a real trace (requests below feed the recorders)
        let req = requests(&server, 1, 4).remove(0);
        server.handle_request(&req, &mut NullCompute).unwrap();
        assert!(!services.drain_access_logs().graph.is_empty());

        // disable again: controller off, invalid values still rejected
        server.reload("adaptive.enabled", "false").unwrap();
        assert!(!ctl.is_enabled());
        let err = server.reload("adaptive.min_gain", "1.5").unwrap_err();
        assert!(err.contains("adaptive.min_gain"), "{err}");
        assert_eq!(ctl.min_gain(), 0.25, "bad reload left state");
    }

    #[test]
    fn admit_token_crosses_threads_and_releases_on_drop() {
        let (server, _tmp) = server_with(|c| c.serve.max_inflight = 1);
        let req = requests(&server, 1, 4).remove(0);

        let token = server.try_admit().unwrap();
        assert_eq!(server.inflight(), 1);
        // the slot is held until the token runs (or drops)
        assert!(matches!(
            server.try_admit().unwrap_err(),
            ServeError::Overloaded { .. }
        ));
        // hand the token to another thread, run there
        let resp = std::thread::scope(|scope| {
            scope.spawn(move || token.run(&req, &mut NullCompute)).join().unwrap()
        })
        .unwrap();
        assert!(resp.nodes > 0);
        assert_eq!(server.inflight(), 0);

        // dropping an unused token releases without executing
        drop(server.try_admit().unwrap());
        assert_eq!(server.inflight(), 0);
        assert_eq!(server.metrics().serve.requests, 1);
    }
}
