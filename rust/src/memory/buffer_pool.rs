//! Block buffer with LRU-and-pinning replacement.
//!
//! The paper (§3.4 (1)): "AGNES uses dynamic caching based on an LRU
//! mechanism … to pin graph blocks already in the graph buffer (e.g., the
//! blocks processed in previous iterations) to prevent them from being
//! replaced until they are completely processed in the current iteration.
//! AGNES unpins these blocks after they are completely processed."
//!
//! The pool is generic over the cached value (decoded [`GraphBlock`]s for
//! the graph buffer, raw bytes for the feature buffer) and doubles as the
//! buffer index table `T_buf` — `get` *is* the table lookup.
//!
//! Under `cache.policy = belady` ([`super::trace`]) a precomputed
//! schedule replaces LRU victim selection: the evicted frame is the
//! unpinned one whose next scheduled use is farthest in the future
//! (ties broken oldest-LRU-first, which keeps bridged-gap padding blocks
//! — inserted first, never in the trace — the preferred victims). With no
//! schedule installed the pool is bit-for-bit the LRU it always was.

use super::trace::{AccessLog, BeladySchedule, ScheduleCursor, TraceRecorder};
use crate::storage::BlockId;
use std::cmp::Reverse;
use std::collections::HashMap;
use std::sync::Arc;

/// Hit/miss/eviction counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// `insert` calls rejected because every frame was pinned.
    pub pin_stalls: u64,
}

impl PoolStats {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Frame<V> {
    value: Arc<V>,
    pin_count: u32,
    /// LRU timestamp (monotone counter).
    last_used: u64,
    /// Next scheduled use (meaningful only when a schedule is installed).
    next_use: u64,
}

/// An LRU block buffer with per-block pin counts. Capacity is in blocks
/// (the byte budget divided by the block size — both layers' buffers are
/// sized that way in the paper's memory settings).
pub struct BufferPool<V> {
    capacity: usize,
    frames: HashMap<BlockId, Frame<V>>,
    clock: u64,
    stats: PoolStats,
    recorder: TraceRecorder<BlockId>,
    cursor: Option<ScheduleCursor<BlockId>>,
}

impl<V> BufferPool<V> {
    pub fn new(capacity: usize) -> BufferPool<V> {
        assert!(capacity >= 1, "buffer needs at least one frame");
        BufferPool {
            capacity,
            frames: HashMap::with_capacity(capacity),
            clock: 0,
            stats: PoolStats::default(),
            recorder: TraceRecorder::new(),
            cursor: None,
        }
    }

    /// Start recording the access trace (see [`super::trace`]); stays on.
    pub fn start_recording(&mut self) {
        self.recorder.enable();
    }

    /// Open hyperbatch `h` for both the recorder and (if installed) the
    /// schedule cursor.
    pub fn begin_hyperbatch(&mut self, h: usize) {
        self.recorder.begin_hyperbatch(h);
        if let Some(c) = &mut self.cursor {
            c.begin_hyperbatch(h);
        }
    }

    /// Drain the recorded access log (empty unless recording).
    pub fn take_log(&mut self) -> AccessLog<BlockId> {
        self.recorder.take()
    }

    /// Switch victim selection to the given Belady schedule, starting at
    /// position 0. Resident frames are re-keyed by their next scheduled
    /// use.
    pub fn install_schedule(&mut self, schedule: BeladySchedule<BlockId>) {
        let cursor = ScheduleCursor::new(schedule);
        for (b, f) in self.frames.iter_mut() {
            f.next_use = cursor.peek_next_use(b);
        }
        self.cursor = Some(cursor);
    }

    /// Drop any partial trace and rewind an installed schedule to position
    /// 0 (bench pass boundaries); recording stays enabled.
    pub fn restart_trace(&mut self) {
        self.recorder.restart();
        if let Some(c) = &mut self.cursor {
            c.rewind();
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = PoolStats::default();
    }

    /// Buffer-index-table lookup: returns the cached block and bumps LRU.
    /// Counts a hit or miss.
    pub fn get(&mut self, b: BlockId) -> Option<Arc<V>> {
        self.clock += 1;
        self.recorder.record(b);
        let next = self.cursor.as_mut().map(|c| c.on_access(&b));
        match self.frames.get_mut(&b) {
            Some(f) => {
                f.last_used = self.clock;
                if let Some(n) = next {
                    f.next_use = n;
                }
                self.stats.hits += 1;
                Some(f.value.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Peek without touching LRU order or stats.
    pub fn contains(&self, b: BlockId) -> bool {
        self.frames.contains_key(&b)
    }

    /// Fetch without counting hit/miss stats (bumps LRU). Used for the
    /// second lookup of a block within one sweep run so hit ratios reflect
    /// block-level accesses, not implementation double-checks.
    pub fn peek(&mut self, b: BlockId) -> Option<Arc<V>> {
        self.clock += 1;
        self.frames.get_mut(&b).map(|f| {
            f.last_used = self.clock;
            f.value.clone()
        })
    }

    /// Insert a block, evicting the LRU *unpinned* frame if full. Returns
    /// the evicted block id, if any. If every frame is pinned the pool
    /// grows transiently (stall counted) — the coordinator sizes hyperbatch
    /// pins below capacity so this is exceptional, not the steady state.
    pub fn insert(&mut self, b: BlockId, value: Arc<V>) -> Option<BlockId> {
        self.clock += 1;
        if let Some(f) = self.frames.get_mut(&b) {
            f.value = value;
            f.last_used = self.clock;
            return None;
        }
        let mut evicted = None;
        if self.frames.len() >= self.capacity {
            // belady: farthest next use, oldest-LRU tie-break (unique
            // last_used makes the choice deterministic and keeps padding
            // blocks — never in the trace, inserted first — the preferred
            // victims). Reactive: plain LRU.
            let victim = match &self.cursor {
                Some(_) => self
                    .frames
                    .iter()
                    .filter(|(_, f)| f.pin_count == 0)
                    .max_by_key(|(_, f)| (f.next_use, Reverse(f.last_used)))
                    .map(|(&id, _)| id),
                None => self
                    .frames
                    .iter()
                    .filter(|(_, f)| f.pin_count == 0)
                    .min_by_key(|(_, f)| f.last_used)
                    .map(|(&id, _)| id),
            };
            match victim {
                Some(id) => {
                    self.frames.remove(&id);
                    self.stats.evictions += 1;
                    evicted = Some(id);
                }
                None => {
                    self.stats.pin_stalls += 1;
                }
            }
        }
        let next_use = match &self.cursor {
            Some(c) => c.peek_next_use(&b),
            None => 0,
        };
        self.frames.insert(b, Frame { value, pin_count: 0, last_used: self.clock, next_use });
        evicted
    }

    /// Pin a resident block (no-op if absent). Pins nest.
    pub fn pin(&mut self, b: BlockId) {
        if let Some(f) = self.frames.get_mut(&b) {
            f.pin_count += 1;
        }
    }

    /// Unpin a resident block (saturating).
    pub fn unpin(&mut self, b: BlockId) {
        if let Some(f) = self.frames.get_mut(&b) {
            f.pin_count = f.pin_count.saturating_sub(1);
        }
    }

    /// Number of currently pinned frames.
    pub fn pinned(&self) -> usize {
        self.frames.values().filter(|f| f.pin_count > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(cap: usize) -> BufferPool<u32> {
        BufferPool::new(cap)
    }

    #[test]
    fn hit_miss_accounting() {
        let mut p = pool(2);
        assert!(p.get(BlockId(1)).is_none());
        p.insert(BlockId(1), Arc::new(10));
        assert_eq!(*p.get(BlockId(1)).unwrap(), 10);
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.hit_ratio(), 0.5);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut p = pool(2);
        p.insert(BlockId(1), Arc::new(1));
        p.insert(BlockId(2), Arc::new(2));
        p.get(BlockId(1)); // 2 is now LRU
        let evicted = p.insert(BlockId(3), Arc::new(3));
        assert_eq!(evicted, Some(BlockId(2)));
        assert!(p.contains(BlockId(1)) && p.contains(BlockId(3)));
    }

    #[test]
    fn pinned_blocks_survive_eviction() {
        let mut p = pool(2);
        p.insert(BlockId(1), Arc::new(1));
        p.insert(BlockId(2), Arc::new(2));
        p.pin(BlockId(1));
        p.get(BlockId(1)); // 1 is MRU *and* pinned; 2 is victim
        p.insert(BlockId(3), Arc::new(3));
        // now 3 is MRU, 1 pinned; inserting 4 must evict 3, not 1
        p.get(BlockId(1));
        let evicted = p.insert(BlockId(4), Arc::new(4));
        assert_eq!(evicted, Some(BlockId(3)));
        assert!(p.contains(BlockId(1)));
    }

    #[test]
    fn all_pinned_stalls_but_grows() {
        let mut p = pool(1);
        p.insert(BlockId(1), Arc::new(1));
        p.pin(BlockId(1));
        let evicted = p.insert(BlockId(2), Arc::new(2));
        assert_eq!(evicted, None);
        assert_eq!(p.stats().pin_stalls, 1);
        assert_eq!(p.len(), 2); // transient overflow
    }

    #[test]
    fn unpin_restores_evictability() {
        let mut p = pool(1);
        p.insert(BlockId(1), Arc::new(1));
        p.pin(BlockId(1));
        p.unpin(BlockId(1));
        let evicted = p.insert(BlockId(2), Arc::new(2));
        assert_eq!(evicted, Some(BlockId(1)));
    }

    #[test]
    fn pins_nest() {
        let mut p = pool(1);
        p.insert(BlockId(1), Arc::new(1));
        p.pin(BlockId(1));
        p.pin(BlockId(1));
        p.unpin(BlockId(1));
        assert_eq!(p.pinned(), 1); // still pinned once
        p.unpin(BlockId(1));
        assert_eq!(p.pinned(), 0);
    }

    #[test]
    fn reinsert_updates_value() {
        let mut p = pool(2);
        p.insert(BlockId(1), Arc::new(1));
        p.insert(BlockId(1), Arc::new(99));
        assert_eq!(*p.get(BlockId(1)).unwrap(), 99);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn belady_pool_evicts_farthest_next_use() {
        // trace 1 2 3 1: block 2 is never reused — belady must evict it,
        // while LRU would have evicted 1 (the block that is reused)
        let mut p = pool(2);
        p.start_recording();
        for b in [1u32, 2, 3, 1] {
            p.get(BlockId(b));
        }
        let log = p.take_log();
        p.install_schedule(BeladySchedule::build(&log));
        p.begin_hyperbatch(0);
        assert!(p.get(BlockId(1)).is_none());
        p.insert(BlockId(1), Arc::new(1));
        assert!(p.get(BlockId(2)).is_none());
        p.insert(BlockId(2), Arc::new(2));
        assert!(p.get(BlockId(3)).is_none());
        let evicted = p.insert(BlockId(3), Arc::new(3));
        assert_eq!(evicted, Some(BlockId(2)), "the dead block is the victim");
        assert!(p.get(BlockId(1)).is_some(), "the reused block survived");
    }

    #[test]
    fn belady_prefers_oldest_on_next_use_ties() {
        // frames absent from the trace tie at next_use = MAX; the oldest
        // insert (padding blocks land first) must be the victim
        let mut p = pool(2);
        let log = AccessLog { hyperbatches: vec![vec![BlockId(1)]] };
        p.install_schedule(BeladySchedule::build(&log));
        p.insert(BlockId(8), Arc::new(8));
        p.insert(BlockId(9), Arc::new(9));
        let evicted = p.insert(BlockId(1), Arc::new(1));
        assert_eq!(evicted, Some(BlockId(8)));
    }

    #[test]
    fn belady_respects_pins() {
        let mut p = pool(2);
        let log = AccessLog { hyperbatches: vec![vec![BlockId(1)]] };
        p.install_schedule(BeladySchedule::build(&log));
        p.insert(BlockId(5), Arc::new(5));
        p.insert(BlockId(6), Arc::new(6));
        p.pin(BlockId(5));
        let evicted = p.insert(BlockId(1), Arc::new(1));
        assert_eq!(evicted, Some(BlockId(6)), "pinned frame survives even at equal next use");
    }

    #[test]
    fn restart_trace_rewinds_schedule() {
        let mut p = pool(2);
        p.start_recording();
        for b in [1u32, 2, 1] {
            p.get(BlockId(b));
        }
        let log = p.take_log();
        p.install_schedule(BeladySchedule::build(&log));
        p.get(BlockId(1)); // advances the cursor past position 0
        p.restart_trace();
        // after rewind the first position is live again
        p.insert(BlockId(1), Arc::new(1));
        p.get(BlockId(1));
        assert!(p.take_log().total() > 0, "recording stays on across restart");
    }
}
