//! In-memory layer (paper §3.2 layer 2): graph/feature buffers with their
//! buffer index tables (`T_buf^g`, `T_buf^f`), the LRU-with-pinning
//! replacement policy of §3.4 (1), the access-count-threshold feature
//! cache (`C_f`, `T_ch^f`) of §3.4 (2), and the trace-optimal
//! (Belady/MIN) eviction machinery of [`trace`].

pub mod buffer_pool;
pub mod feature_cache;
pub mod shared;
pub mod trace;

pub use buffer_pool::{BufferPool, PoolStats};
pub use feature_cache::{FeatureCache, FeatureCacheStats};
pub use shared::{SharedBufferPool, SharedFeatureCache};
pub use trace::{AccessLog, BeladySchedule, CachePolicy, ScheduleCursor, TraceRecorder};
