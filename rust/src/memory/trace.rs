//! Trace-optimal caching: a shared access-trace recorder and precomputed
//! Belady/MIN eviction schedules for the feature cache and buffer pools.
//!
//! Ginex (PAPERS.md) shows that once storage I/O is block-wise, the
//! dominant remaining win is *provably-optimal* in-memory caching driven
//! by the (known, repeating) per-epoch access trace. AGNES already has a
//! deterministic access sequence per hyperbatch: sampling is seeded
//! per-slot and gathering sweeps the miss set in a fixed order, so the
//! block/vector access stream of one epoch predicts the next. This module
//! turns that stream into eviction decisions:
//!
//! 1. [`TraceRecorder`] captures the per-hyperbatch access sequence as it
//!    happens, inside the cache/pool structures themselves — one branch
//!    per access when disabled, no extra locking on the hot path (the
//!    shared-handle mutex the sweeps already hold covers the recorder).
//!    It is the live counterpart of the *sampled* trace in
//!    [`crate::graph::reorder::sample_access_trace`]: reorder's trace is a
//!    structural stand-in used before any epoch runs (block placement);
//!    this one is the exact stream, used for eviction. Both speak
//!    per-hyperbatch, so a future self-tuning controller (ROADMAP) can
//!    consume either.
//! 2. [`BeladySchedule::build`] turns an [`AccessLog`] into per-key
//!    ascending global access positions plus per-hyperbatch start
//!    offsets.
//! 3. [`ScheduleCursor`] walks the schedule during the next epoch:
//!    `on_access` advances the global position and returns the key's next
//!    use ("farthest next use" is the Belady/MIN eviction victim);
//!    `begin_hyperbatch` re-synchronizes the position at every hyperbatch
//!    boundary, so a trace that drifts (e.g. the feature-block miss set
//!    shifts with cache contents) degrades gracefully instead of
//!    compounding.
//!
//! The policy knob ([`CachePolicy`]) is plumbed through `cache.policy` /
//! `--cache-policy` / `AGNES_CACHE_POLICY`. `reactive` is the bit-for-bit
//! historical behavior; `belady` records epoch 0 live under reactive
//! semantics and switches to the precomputed schedule from epoch 1 on
//! ("warmup-then-optimal"). Either way the *training values* are
//! identical: caching changes residency and modeled I/O time, never the
//! gathered bytes (property-tested in the coordinator).

use std::collections::HashMap;
use std::hash::Hash;

/// Which eviction policy the feature cache and buffer pools run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CachePolicy {
    /// Historical reactive policies: access-count admission + coldest-first
    /// eviction for the feature cache, LRU for the buffer pools.
    #[default]
    Reactive,
    /// Belady/MIN: record epoch 0, then evict the entry whose next use is
    /// farthest in the future according to the previous epoch's trace.
    Belady,
}

impl CachePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Reactive => "reactive",
            CachePolicy::Belady => "belady",
        }
    }

    pub fn all() -> [CachePolicy; 2] {
        [CachePolicy::Reactive, CachePolicy::Belady]
    }
}

impl std::str::FromStr for CachePolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "reactive" => Ok(CachePolicy::Reactive),
            "belady" => Ok(CachePolicy::Belady),
            other => Err(format!("unknown cache policy {other:?} (expected reactive | belady)")),
        }
    }
}

impl std::fmt::Display for CachePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One epoch's recorded access stream, split per hyperbatch. Produced by
/// [`TraceRecorder::take`], consumed by [`BeladySchedule::build`].
#[derive(Debug, Clone, Default)]
pub struct AccessLog<K> {
    pub hyperbatches: Vec<Vec<K>>,
}

impl<K> AccessLog<K> {
    /// Total recorded accesses.
    pub fn total(&self) -> usize {
        self.hyperbatches.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }
}

/// Records the per-hyperbatch access sequence of a cache or pool. Lives
/// *inside* the cached structure so recording happens under the lock the
/// sweep already holds — disabled, it is a single branch per access.
#[derive(Debug)]
pub struct TraceRecorder<K> {
    enabled: bool,
    hyperbatches: Vec<Vec<K>>,
    current: usize,
}

impl<K> Default for TraceRecorder<K> {
    fn default() -> Self {
        TraceRecorder { enabled: false, hyperbatches: Vec::new(), current: 0 }
    }
}

impl<K: Copy> TraceRecorder<K> {
    pub fn new() -> TraceRecorder<K> {
        TraceRecorder::default()
    }

    /// Turn recording on (stays on; each epoch's log refreshes the next
    /// epoch's schedule).
    pub fn enable(&mut self) {
        self.enabled = true;
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Open hyperbatch `h`'s bucket; subsequent [`Self::record`] calls land
    /// there. Skipped hyperbatch indices leave empty buckets, keeping
    /// bucket index == hyperbatch index.
    pub fn begin_hyperbatch(&mut self, h: usize) {
        if !self.enabled {
            return;
        }
        while self.hyperbatches.len() <= h {
            self.hyperbatches.push(Vec::new());
        }
        self.current = h;
    }

    /// Append one access to the current hyperbatch's bucket.
    #[inline]
    pub fn record(&mut self, k: K) {
        if !self.enabled {
            return;
        }
        if self.hyperbatches.is_empty() {
            self.hyperbatches.push(Vec::new());
            self.current = 0;
        }
        self.hyperbatches[self.current].push(k);
    }

    /// Drain the recorded log (recording stays enabled; the next epoch
    /// starts a fresh log).
    pub fn take(&mut self) -> AccessLog<K> {
        self.current = 0;
        AccessLog { hyperbatches: std::mem::take(&mut self.hyperbatches) }
    }

    /// Drop any partial log without touching the enabled flag (counter
    /// resets between bench passes).
    pub fn restart(&mut self) {
        self.hyperbatches.clear();
        self.current = 0;
    }
}

/// Precomputed Belady/MIN schedule: every key's ascending global access
/// positions plus each hyperbatch's starting position. Built once per
/// epoch from the previous epoch's [`AccessLog`].
#[derive(Debug, Clone, Default)]
pub struct BeladySchedule<K> {
    positions: HashMap<K, Vec<u64>>,
    /// Global position at which each hyperbatch's accesses begin.
    offsets: Vec<u64>,
    total: u64,
}

impl<K: Copy + Eq + Hash> BeladySchedule<K> {
    pub fn build(log: &AccessLog<K>) -> BeladySchedule<K> {
        let mut positions: HashMap<K, Vec<u64>> = HashMap::new();
        let mut offsets = Vec::with_capacity(log.hyperbatches.len());
        let mut pos = 0u64;
        for hb in &log.hyperbatches {
            offsets.push(pos);
            for &k in hb {
                positions.entry(k).or_default().push(pos);
                pos += 1;
            }
        }
        BeladySchedule { positions, offsets, total: pos }
    }

    /// Total positions in the schedule.
    pub fn len(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Number of distinct keys in the trace.
    pub fn distinct(&self) -> usize {
        self.positions.len()
    }
}

/// A walk over a [`BeladySchedule`] during the epoch it predicts. The
/// cursor is the global position of the *next* expected access; a key's
/// "next use" is its first scheduled position at or after the cursor
/// (`u64::MAX` = never used again — the ideal eviction victim).
#[derive(Debug, Clone)]
pub struct ScheduleCursor<K> {
    schedule: BeladySchedule<K>,
    cursor: u64,
}

impl<K: Copy + Eq + Hash> ScheduleCursor<K> {
    pub fn new(schedule: BeladySchedule<K>) -> ScheduleCursor<K> {
        ScheduleCursor { schedule, cursor: 0 }
    }

    /// Restart the walk (epoch boundary: the same schedule replays).
    pub fn rewind(&mut self) {
        self.cursor = 0;
    }

    /// Re-synchronize at a hyperbatch boundary: jump to the hyperbatch's
    /// recorded start position (never backwards). Bounds the drift of a
    /// live stream that diverges from the recorded trace mid-hyperbatch.
    pub fn begin_hyperbatch(&mut self, h: usize) {
        let target = self.schedule.offsets.get(h).copied().unwrap_or(self.schedule.total);
        self.cursor = self.cursor.max(target);
    }

    /// Consume one access: advance the global position and return `k`'s
    /// next scheduled use after it.
    #[inline]
    pub fn on_access(&mut self, k: &K) -> u64 {
        self.cursor += 1;
        self.next_from(k)
    }

    /// `k`'s next scheduled use at or after the current position, without
    /// consuming anything (admission decisions).
    #[inline]
    pub fn peek_next_use(&self, k: &K) -> u64 {
        self.next_from(k)
    }

    fn next_from(&self, k: &K) -> u64 {
        match self.schedule.positions.get(k) {
            Some(list) => {
                let i = list.partition_point(|&p| p < self.cursor);
                list.get(i).copied().unwrap_or(u64::MAX)
            }
            None => u64::MAX,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log(hbs: &[&[u32]]) -> AccessLog<u32> {
        AccessLog { hyperbatches: hbs.iter().map(|h| h.to_vec()).collect() }
    }

    #[test]
    fn policy_parses_and_displays() {
        use std::str::FromStr;
        for p in CachePolicy::all() {
            assert_eq!(CachePolicy::from_str(p.name()).unwrap(), p);
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(CachePolicy::from_str("BELADY").unwrap(), CachePolicy::Belady);
        assert!(CachePolicy::from_str("optimal").is_err());
        assert_eq!(CachePolicy::default(), CachePolicy::Reactive);
    }

    #[test]
    fn recorder_disabled_is_a_noop() {
        let mut r: TraceRecorder<u32> = TraceRecorder::new();
        r.begin_hyperbatch(0);
        r.record(1);
        r.record(2);
        assert!(r.take().is_empty());
    }

    #[test]
    fn recorder_buckets_by_hyperbatch() {
        let mut r: TraceRecorder<u32> = TraceRecorder::new();
        r.enable();
        r.begin_hyperbatch(0);
        r.record(1);
        r.record(2);
        r.begin_hyperbatch(2); // skipped index 1 leaves an empty bucket
        r.record(3);
        let l = r.take();
        assert_eq!(l.hyperbatches, vec![vec![1, 2], vec![], vec![3]]);
        assert_eq!(l.total(), 3);
        // taking drains but keeps recording
        r.record(9);
        assert_eq!(r.take().hyperbatches, vec![vec![9]]);
    }

    #[test]
    fn recorder_restart_keeps_enabled() {
        let mut r: TraceRecorder<u32> = TraceRecorder::new();
        r.enable();
        r.record(5);
        r.restart();
        assert!(r.is_enabled());
        assert!(r.take().is_empty());
    }

    #[test]
    fn schedule_positions_and_offsets() {
        let s = BeladySchedule::build(&log(&[&[10, 20, 10], &[20, 30]]));
        assert_eq!(s.len(), 5);
        assert_eq!(s.distinct(), 3);
        let mut c = ScheduleCursor::new(s);
        // position 0: access 10 → next use at 2
        assert_eq!(c.on_access(&10), 2);
        // position 1: access 20 → next use at 3
        assert_eq!(c.on_access(&20), 3);
        // position 2: access 10 → never again
        assert_eq!(c.on_access(&10), u64::MAX);
        c.begin_hyperbatch(1);
        assert_eq!(c.peek_next_use(&20), 3);
        assert_eq!(c.peek_next_use(&30), 4);
        assert_eq!(c.peek_next_use(&99), u64::MAX);
    }

    #[test]
    fn cursor_resyncs_at_hyperbatch_boundaries() {
        let s = BeladySchedule::build(&log(&[&[1, 2], &[1, 3]]));
        let mut c = ScheduleCursor::new(s);
        // live stream diverges: only one access seen in hyperbatch 0
        c.begin_hyperbatch(0);
        c.on_access(&1);
        // boundary resync jumps the cursor to position 2
        c.begin_hyperbatch(1);
        assert_eq!(c.peek_next_use(&1), 2);
        assert_eq!(c.peek_next_use(&2), u64::MAX, "hb0-only key is past");
        // never moves backwards
        c.on_access(&1);
        c.on_access(&3);
        c.begin_hyperbatch(0);
        assert_eq!(c.peek_next_use(&3), u64::MAX);
    }

    #[test]
    fn cursor_rewind_replays() {
        let s = BeladySchedule::build(&log(&[&[7, 8, 7]]));
        let mut c = ScheduleCursor::new(s);
        assert_eq!(c.on_access(&7), 2);
        c.rewind();
        assert_eq!(c.peek_next_use(&7), 0);
        assert_eq!(c.on_access(&7), 2);
    }

    #[test]
    fn recorder_deterministic_under_fixed_seed() {
        // same seeded access stream → identical logs and schedules
        let run = || {
            let mut r: TraceRecorder<u32> = TraceRecorder::new();
            r.enable();
            let mut rng = crate::util::Rng::seed_from_u64(42);
            for h in 0..8 {
                r.begin_hyperbatch(h);
                for _ in 0..200 {
                    r.record(rng.gen_range(64) as u32);
                }
            }
            r.take()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.hyperbatches, b.hyperbatches);
        let (sa, sb) = (BeladySchedule::build(&a), BeladySchedule::build(&b));
        assert_eq!(sa.len(), sb.len());
        assert_eq!(sa.distinct(), sb.distinct());
        for k in 0..64u32 {
            let (mut ca, mut cb) = (ScheduleCursor::new(sa.clone()), ScheduleCursor::new(sb.clone()));
            assert_eq!(ca.on_access(&k), cb.on_access(&k));
        }
    }

    #[test]
    fn belady_never_evicts_a_key_needed_before_a_retained_one() {
        // property: simulate an exact replay of a random trace with a
        // farthest-next-use cache; at every eviction the victim's next use
        // must be >= every retained key's next use (schedule validity)
        let mut rng = crate::util::Rng::seed_from_u64(7);
        for trial in 0..20 {
            let mut r: TraceRecorder<u32> = TraceRecorder::new();
            r.enable();
            for h in 0..4 {
                r.begin_hyperbatch(h);
                for _ in 0..300 {
                    r.record(rng.gen_range(32) as u32);
                }
            }
            let log = r.take();
            let schedule = BeladySchedule::build(&log);
            let mut cursor = ScheduleCursor::new(schedule);
            let capacity = 4 + trial % 8;
            let mut resident: HashMap<u32, u64> = HashMap::new();
            for (h, hb) in log.hyperbatches.iter().enumerate() {
                cursor.begin_hyperbatch(h);
                for &k in hb {
                    let next = cursor.on_access(&k);
                    if let Some(n) = resident.get_mut(&k) {
                        *n = next;
                        continue;
                    }
                    if resident.len() >= capacity {
                        let (&victim, &vnext) =
                            resident.iter().max_by_key(|&(&k, &n)| (n, k)).unwrap();
                        for (&other, &onext) in &resident {
                            assert!(
                                onext <= vnext,
                                "trial {trial}: evicted {victim} (next {vnext}) \
                                 but retained {other} needed later ({onext})"
                            );
                        }
                        resident.remove(&victim);
                    }
                    resident.insert(k, next);
                }
            }
        }
    }

    #[test]
    fn empty_schedule_is_total_miss() {
        let s: BeladySchedule<u32> = BeladySchedule::build(&AccessLog::default());
        assert!(s.is_empty());
        let mut c = ScheduleCursor::new(s);
        assert_eq!(c.on_access(&1), u64::MAX);
        c.begin_hyperbatch(5); // out of range clamps to end
        assert_eq!(c.peek_next_use(&1), u64::MAX);
    }
}
