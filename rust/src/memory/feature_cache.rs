//! Feature cache `C_f` with access-count admission (paper §3.4 (2)).
//!
//! "AGNES counts the number of accesses to each feature vector and
//! maintains only feature vectors whose access counts exceed a certain
//! threshold in a feature cache in main memory. The others are written back
//! to storage at each minibatch and reloaded when they are required."
//!
//! The cache index table `T_ch^f` is the internal hash map. Admission:
//! a vector becomes cache-resident once its lifetime access count passes
//! `threshold`; capacity pressure evicts the *coldest* resident vector
//! (lowest count, then least recently used), tracked in an ordered
//! eviction index so admission and eviction are O(log n) — the original
//! O(capacity) eviction scan was the top bottleneck of the gather hot path
//! (EXPERIMENTS.md §Perf).
//!
//! Under `cache.policy = belady` ([`super::trace`]) the reactive
//! count-threshold rules are replaced by a precomputed Belady/MIN
//! schedule: `get` advances a [`ScheduleCursor`], eviction picks the
//! resident vector whose next use is farthest in the future (a second
//! ordered index keyed by next use), and admission bypasses the count
//! threshold — a vector is admitted iff its next use comes sooner than
//! the current farthest resident's. With no schedule installed (warmup
//! epoch) behavior is bit-for-bit the reactive policy.

use super::trace::{AccessLog, BeladySchedule, ScheduleCursor, TraceRecorder};
use std::collections::{BTreeSet, HashMap};

/// Cache statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct FeatureCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub admissions: u64,
    pub evictions: u64,
}

impl FeatureCacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

struct Entry {
    feature: Vec<f32>,
    /// This entry's current key in the eviction index.
    key: (u32, u64),
    /// This entry's current key in the Belady index (meaningful only when
    /// a schedule is installed).
    next_use: u64,
}

/// Schedule-driven eviction state, present once a Belady schedule has
/// been installed.
struct BeladyState {
    cursor: ScheduleCursor<u32>,
    /// Eviction order: (next_use, node) ascending — the *last* element is
    /// the resident whose next use is farthest in the future.
    index: BTreeSet<(u64, u32)>,
}

/// Access-count-threshold feature cache.
pub struct FeatureCache {
    /// Max resident vectors (memory budget / vector bytes).
    capacity: usize,
    /// Admission threshold on lifetime access count.
    threshold: u32,
    counts: HashMap<u32, u32>,
    resident: HashMap<u32, Entry>,
    /// Eviction order: (count, last_used, node) ascending — the first
    /// element is always the coldest resident.
    evict_index: BTreeSet<(u32, u64, u32)>,
    clock: u64,
    stats: FeatureCacheStats,
    recorder: TraceRecorder<u32>,
    belady: Option<BeladyState>,
}

impl FeatureCache {
    pub fn new(capacity: usize, threshold: u32) -> FeatureCache {
        FeatureCache {
            capacity,
            threshold,
            counts: HashMap::new(),
            resident: HashMap::new(),
            evict_index: BTreeSet::new(),
            clock: 0,
            stats: FeatureCacheStats::default(),
            recorder: TraceRecorder::new(),
            belady: None,
        }
    }

    /// Start recording the access trace (see [`super::trace`]); stays on.
    pub fn start_recording(&mut self) {
        self.recorder.enable();
    }

    /// Open hyperbatch `h` for both the recorder and (if installed) the
    /// schedule cursor.
    pub fn begin_hyperbatch(&mut self, h: usize) {
        self.recorder.begin_hyperbatch(h);
        if let Some(b) = &mut self.belady {
            b.cursor.begin_hyperbatch(h);
        }
    }

    /// Drain the recorded access log (empty unless recording).
    pub fn take_log(&mut self) -> AccessLog<u32> {
        self.recorder.take()
    }

    /// Switch eviction to the given Belady schedule, starting at position
    /// 0. Current residents are re-keyed by their next scheduled use.
    pub fn install_schedule(&mut self, schedule: BeladySchedule<u32>) {
        let cursor = ScheduleCursor::new(schedule);
        let mut index = BTreeSet::new();
        for (&v, e) in self.resident.iter_mut() {
            e.next_use = cursor.peek_next_use(&v);
            index.insert((e.next_use, v));
        }
        self.belady = Some(BeladyState { cursor, index });
    }

    /// Zero counters, residents, and any partial trace, preserving the
    /// recording flag and an installed schedule (bench pass boundaries).
    pub fn reset(&mut self, capacity: usize, threshold: u32) {
        self.capacity = capacity;
        self.threshold = threshold;
        self.counts.clear();
        self.resident.clear();
        self.evict_index.clear();
        self.clock = 0;
        self.stats = FeatureCacheStats::default();
        self.recorder.restart();
        if let Some(b) = &mut self.belady {
            b.cursor.rewind();
            b.index.clear();
        }
    }

    /// Budget in vectors.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    pub fn stats(&self) -> FeatureCacheStats {
        self.stats
    }

    /// Lifetime access count of `v`.
    pub fn count(&self, v: u32) -> u32 {
        self.counts.get(&v).copied().unwrap_or(0)
    }

    /// Look up node `v`'s vector, recording the access. Returns `None` on
    /// miss (caller fetches from the feature store and calls [`Self::fill`]).
    pub fn get(&mut self, v: u32) -> Option<&[f32]> {
        self.clock += 1;
        self.recorder.record(v);
        let count = {
            let c = self.counts.entry(v).or_insert(0);
            *c += 1;
            *c
        };
        if let Some(e) = self.resident.get_mut(&v) {
            self.stats.hits += 1;
            // lazy re-keying: the eviction index only needs the *order* of
            // coldness, so refresh an entry's key when its count has moved
            // meaningfully (+8) — two BTree ops per hit was ~30% of gather
            // (EXPERIMENTS.md §Perf)
            if count >= e.key.0 + 8 {
                let (old_count, old_used) = e.key;
                self.evict_index.remove(&(old_count, old_used, v));
                e.key = (count, self.clock);
                self.evict_index.insert((count, self.clock, v));
            }
            if let Some(b) = &mut self.belady {
                b.index.remove(&(e.next_use, v));
                e.next_use = b.cursor.on_access(&v);
                b.index.insert((e.next_use, v));
            }
            Some(&e.feature)
        } else {
            self.stats.misses += 1;
            if let Some(b) = &mut self.belady {
                b.cursor.on_access(&v);
            }
            None
        }
    }

    /// Would [`Self::fill`] admit `v` right now? Lets the gather hot path
    /// skip materializing a vector that would be rejected anyway.
    pub fn wants(&self, v: u32) -> bool {
        if self.capacity == 0 || self.resident.contains_key(&v) {
            return false;
        }
        if let Some(b) = &self.belady {
            // Belady admission bypasses the count threshold: admit iff the
            // vector is used again, and (at capacity) sooner than the
            // resident whose next use is farthest away
            let next = b.cursor.peek_next_use(&v);
            if next == u64::MAX {
                return false;
            }
            return if self.resident.len() >= self.capacity {
                match b.index.iter().next_back() {
                    Some(&(victim_next, _)) => next < victim_next,
                    None => false,
                }
            } else {
                true
            };
        }
        let count = self.count(v);
        if count < self.threshold {
            return false;
        }
        if self.resident.len() >= self.capacity {
            match self.evict_index.iter().next() {
                Some(&(victim_count, _, _)) => victim_count < count,
                None => false,
            }
        } else {
            true
        }
    }

    /// Offer a fetched vector for admission. Admits only when the lifetime
    /// count exceeds the threshold ("infrequently accessed feature vectors
    /// are written back to storage at each minibatch") and, at capacity,
    /// only over a strictly colder incumbent (no thrash).
    pub fn fill(&mut self, v: u32, feature: Vec<f32>) {
        if !self.wants(v) {
            return;
        }
        if self.resident.len() >= self.capacity {
            if let Some(b) = &mut self.belady {
                // farthest-next-use victim (both indexes stay in sync)
                if let Some(&(n, victim)) = b.index.iter().next_back() {
                    b.index.remove(&(n, victim));
                    if let Some(e) = self.resident.remove(&victim) {
                        self.evict_index.remove(&(e.key.0, e.key.1, victim));
                    }
                    self.stats.evictions += 1;
                }
            } else if let Some(&(c, u, victim)) = self.evict_index.iter().next() {
                self.evict_index.remove(&(c, u, victim));
                self.resident.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.clock += 1;
        let key = (self.count(v), self.clock);
        self.evict_index.insert((key.0, key.1, v));
        let next_use = match &mut self.belady {
            Some(b) => {
                let n = b.cursor.peek_next_use(&v);
                b.index.insert((n, v));
                n
            }
            None => 0,
        };
        self.resident.insert(v, Entry { feature, key, next_use });
        self.stats.admissions += 1;
    }

    /// Drop all residents but keep counts (epoch boundary).
    pub fn clear_resident(&mut self) {
        self.resident.clear();
        self.evict_index.clear();
        if let Some(b) = &mut self.belady {
            b.index.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(v: u32) -> Vec<f32> {
        vec![v as f32; 4]
    }

    #[test]
    fn below_threshold_not_admitted() {
        let mut c = FeatureCache::new(10, 3);
        assert!(c.get(1).is_none());
        c.fill(1, f(1)); // count 1 < 3
        assert!(c.get(1).is_none());
        c.fill(1, f(1)); // count 2 < 3
        assert!(c.get(1).is_none()); // count now 3
        c.fill(1, f(1)); // admitted
        assert_eq!(c.get(1).unwrap(), &f(1)[..]);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hot_node_evicts_cold_when_full() {
        let mut c = FeatureCache::new(1, 1);
        c.get(1);
        c.fill(1, f(1));
        assert!(c.get(1).is_some()); // count(1) = 2 now
        // node 2 becomes hotter
        for _ in 0..5 {
            c.get(2);
        }
        assert!(c.wants(2));
        c.fill(2, f(2));
        assert!(c.get(2).is_some());
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn cold_node_does_not_thrash_hot_incumbent() {
        let mut c = FeatureCache::new(1, 1);
        for _ in 0..10 {
            c.get(1);
        }
        c.fill(1, f(1));
        c.get(2);
        c.get(2);
        assert!(!c.wants(2)); // count(2)=2 < count(1)=10
        c.fill(2, f(2));
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
    }

    #[test]
    fn zero_capacity_never_admits() {
        let mut c = FeatureCache::new(0, 0);
        c.get(1);
        assert!(!c.wants(1));
        c.fill(1, f(1));
        assert!(c.get(1).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn clear_resident_keeps_counts() {
        let mut c = FeatureCache::new(4, 2);
        for _ in 0..3 {
            c.get(7);
        }
        c.fill(7, f(7));
        assert!(c.get(7).is_some());
        c.clear_resident();
        assert!(c.get(7).is_none());
        assert!(c.count(7) >= 3); // counts survive
        c.fill(7, f(7));
        assert!(c.get(7).is_some()); // immediate re-admission (already hot)
    }

    #[test]
    fn hit_ratio_math() {
        let mut c = FeatureCache::new(4, 0);
        c.get(1);
        c.fill(1, f(1));
        c.get(1);
        c.get(1);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn eviction_index_consistent_under_churn() {
        // stress: random access pattern must keep index and map in sync
        let mut c = FeatureCache::new(8, 1);
        let mut rng = crate::util::Rng::seed_from_u64(1);
        for _ in 0..5000 {
            let v = rng.gen_range(64) as u32;
            if c.get(v).is_none() {
                c.fill(v, f(v));
            }
        }
        assert!(c.len() <= 8);
        assert_eq!(c.evict_index.len(), c.resident.len());
        // every resident has a matching index entry
        for (&v, e) in &c.resident {
            assert!(c.evict_index.contains(&(e.key.0, e.key.1, v)), "node {v} key desync");
        }
    }

    /// Replay a trace through the cache: every miss offers a fill.
    fn replay(c: &mut FeatureCache, hbs: &[&[u32]]) {
        for (h, hb) in hbs.iter().enumerate() {
            c.begin_hyperbatch(h);
            for &v in *hb {
                if c.get(v).is_none() {
                    c.fill(v, f(v));
                }
            }
        }
    }

    #[test]
    fn belady_evicts_farthest_next_use() {
        // capacity 2, trace: 1 2 3 1 2 — on filling 3, reactive-LFU would
        // keep whichever is coldest; belady must evict 3's worst rival:
        // the victim whose next use is farthest (none reused later than 3?
        // here 3 is never reused, so 3 itself must NOT displace 1 or 2)
        let trace: &[&[u32]] = &[&[1, 2, 3, 1, 2]];
        let mut c = FeatureCache::new(2, 1);
        c.start_recording();
        replay(&mut c, trace); // warmup records
        let log = c.take_log();
        let mut c2 = FeatureCache::new(2, 1);
        c2.install_schedule(crate::memory::trace::BeladySchedule::build(&log));
        replay(&mut c2, trace);
        // 3 is never reused → bypassed; 1 and 2 hit on their second use
        let s = c2.stats();
        assert_eq!(s.hits, 2, "belady must keep 1 and 2 resident");
        assert_eq!(s.evictions, 0, "the dead vector is never admitted");
    }

    #[test]
    fn belady_beats_reactive_on_phase_change() {
        // phase change: hot working set A (0..8) goes dead, B (50..58)
        // takes over. Count-based admission keeps A until B's counts
        // out-grow it; belady sees A's next use is never and admits B at
        // its first access
        let mut hb1: Vec<u32> = Vec::new();
        let mut hb2: Vec<u32> = Vec::new();
        for _ in 0..5 {
            hb1.extend(0..8u32);
            hb2.extend(50..58u32);
        }
        let trace: Vec<&[u32]> = vec![&hb1, &hb2];
        let mut reactive = FeatureCache::new(8, 1);
        replay(&mut reactive, &trace);
        let mut warm = FeatureCache::new(8, 1);
        warm.start_recording();
        replay(&mut warm, &trace);
        let log = warm.take_log();
        let mut belady = FeatureCache::new(8, 1);
        belady.install_schedule(crate::memory::trace::BeladySchedule::build(&log));
        replay(&mut belady, &trace);
        assert!(
            belady.stats().hit_ratio() > reactive.stats().hit_ratio(),
            "belady {:?} must beat reactive {:?}",
            belady.stats(),
            reactive.stats()
        );
    }

    #[test]
    fn belady_reset_preserves_schedule() {
        let trace: &[&[u32]] = &[&[4, 5, 4, 5]];
        let mut c = FeatureCache::new(2, 1);
        c.start_recording();
        replay(&mut c, trace);
        let log = c.take_log();
        c.install_schedule(crate::memory::trace::BeladySchedule::build(&log));
        c.reset(2, 1);
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats().hits + c.stats().misses, 0);
        replay(&mut c, trace);
        assert_eq!(c.stats().hits, 2, "schedule survives reset and replays");
        assert!(c.take_log().total() > 0, "recording flag survives reset");
    }

    #[test]
    fn belady_indexes_stay_in_sync_under_churn() {
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let mut hbs: Vec<Vec<u32>> = Vec::new();
        for _ in 0..4 {
            hbs.push((0..800).map(|_| rng.gen_range(48) as u32).collect());
        }
        let trace: Vec<&[u32]> = hbs.iter().map(|h| &h[..]).collect();
        let mut c = FeatureCache::new(8, 1);
        c.start_recording();
        replay(&mut c, &trace);
        let log = c.take_log();
        c.reset(8, 1);
        c.install_schedule(crate::memory::trace::BeladySchedule::build(&log));
        replay(&mut c, &trace);
        assert!(c.len() <= 8);
        let b = c.belady.as_ref().unwrap();
        assert_eq!(b.index.len(), c.resident.len());
        assert_eq!(c.evict_index.len(), c.resident.len());
        for (&v, e) in &c.resident {
            assert!(b.index.contains(&(e.next_use, v)), "node {v} belady key desync");
            assert!(c.evict_index.contains(&(e.key.0, e.key.1, v)), "node {v} key desync");
        }
    }
}
