//! Feature cache `C_f` with access-count admission (paper §3.4 (2)).
//!
//! "AGNES counts the number of accesses to each feature vector and
//! maintains only feature vectors whose access counts exceed a certain
//! threshold in a feature cache in main memory. The others are written back
//! to storage at each minibatch and reloaded when they are required."
//!
//! The cache index table `T_ch^f` is the internal hash map. Admission:
//! a vector becomes cache-resident once its lifetime access count passes
//! `threshold`; capacity pressure evicts the *coldest* resident vector
//! (lowest count, then least recently used), tracked in an ordered
//! eviction index so admission and eviction are O(log n) — the original
//! O(capacity) eviction scan was the top bottleneck of the gather hot path
//! (EXPERIMENTS.md §Perf).

use std::collections::{BTreeSet, HashMap};

/// Cache statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct FeatureCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub admissions: u64,
    pub evictions: u64,
}

impl FeatureCacheStats {
    pub fn hit_ratio(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

struct Entry {
    feature: Vec<f32>,
    /// This entry's current key in the eviction index.
    key: (u32, u64),
}

/// Access-count-threshold feature cache.
pub struct FeatureCache {
    /// Max resident vectors (memory budget / vector bytes).
    capacity: usize,
    /// Admission threshold on lifetime access count.
    threshold: u32,
    counts: HashMap<u32, u32>,
    resident: HashMap<u32, Entry>,
    /// Eviction order: (count, last_used, node) ascending — the first
    /// element is always the coldest resident.
    evict_index: BTreeSet<(u32, u64, u32)>,
    clock: u64,
    stats: FeatureCacheStats,
}

impl FeatureCache {
    pub fn new(capacity: usize, threshold: u32) -> FeatureCache {
        FeatureCache {
            capacity,
            threshold,
            counts: HashMap::new(),
            resident: HashMap::new(),
            evict_index: BTreeSet::new(),
            clock: 0,
            stats: FeatureCacheStats::default(),
        }
    }

    /// Budget in vectors.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    pub fn stats(&self) -> FeatureCacheStats {
        self.stats
    }

    /// Lifetime access count of `v`.
    pub fn count(&self, v: u32) -> u32 {
        self.counts.get(&v).copied().unwrap_or(0)
    }

    /// Look up node `v`'s vector, recording the access. Returns `None` on
    /// miss (caller fetches from the feature store and calls [`Self::fill`]).
    pub fn get(&mut self, v: u32) -> Option<&[f32]> {
        self.clock += 1;
        let count = {
            let c = self.counts.entry(v).or_insert(0);
            *c += 1;
            *c
        };
        if let Some(e) = self.resident.get_mut(&v) {
            self.stats.hits += 1;
            // lazy re-keying: the eviction index only needs the *order* of
            // coldness, so refresh an entry's key when its count has moved
            // meaningfully (+8) — two BTree ops per hit was ~30% of gather
            // (EXPERIMENTS.md §Perf)
            if count >= e.key.0 + 8 {
                let (old_count, old_used) = e.key;
                self.evict_index.remove(&(old_count, old_used, v));
                e.key = (count, self.clock);
                self.evict_index.insert((count, self.clock, v));
            }
            Some(&e.feature)
        } else {
            self.stats.misses += 1;
            None
        }
    }

    /// Would [`Self::fill`] admit `v` right now? Lets the gather hot path
    /// skip materializing a vector that would be rejected anyway.
    pub fn wants(&self, v: u32) -> bool {
        if self.capacity == 0 || self.resident.contains_key(&v) {
            return false;
        }
        let count = self.count(v);
        if count < self.threshold {
            return false;
        }
        if self.resident.len() >= self.capacity {
            match self.evict_index.iter().next() {
                Some(&(victim_count, _, _)) => victim_count < count,
                None => false,
            }
        } else {
            true
        }
    }

    /// Offer a fetched vector for admission. Admits only when the lifetime
    /// count exceeds the threshold ("infrequently accessed feature vectors
    /// are written back to storage at each minibatch") and, at capacity,
    /// only over a strictly colder incumbent (no thrash).
    pub fn fill(&mut self, v: u32, feature: Vec<f32>) {
        if !self.wants(v) {
            return;
        }
        if self.resident.len() >= self.capacity {
            if let Some(&(c, u, victim)) = self.evict_index.iter().next() {
                self.evict_index.remove(&(c, u, victim));
                self.resident.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.clock += 1;
        let key = (self.count(v), self.clock);
        self.evict_index.insert((key.0, key.1, v));
        self.resident.insert(v, Entry { feature, key });
        self.stats.admissions += 1;
    }

    /// Drop all residents but keep counts (epoch boundary).
    pub fn clear_resident(&mut self) {
        self.resident.clear();
        self.evict_index.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(v: u32) -> Vec<f32> {
        vec![v as f32; 4]
    }

    #[test]
    fn below_threshold_not_admitted() {
        let mut c = FeatureCache::new(10, 3);
        assert!(c.get(1).is_none());
        c.fill(1, f(1)); // count 1 < 3
        assert!(c.get(1).is_none());
        c.fill(1, f(1)); // count 2 < 3
        assert!(c.get(1).is_none()); // count now 3
        c.fill(1, f(1)); // admitted
        assert_eq!(c.get(1).unwrap(), &f(1)[..]);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hot_node_evicts_cold_when_full() {
        let mut c = FeatureCache::new(1, 1);
        c.get(1);
        c.fill(1, f(1));
        assert!(c.get(1).is_some()); // count(1) = 2 now
        // node 2 becomes hotter
        for _ in 0..5 {
            c.get(2);
        }
        assert!(c.wants(2));
        c.fill(2, f(2));
        assert!(c.get(2).is_some());
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn cold_node_does_not_thrash_hot_incumbent() {
        let mut c = FeatureCache::new(1, 1);
        for _ in 0..10 {
            c.get(1);
        }
        c.fill(1, f(1));
        c.get(2);
        c.get(2);
        assert!(!c.wants(2)); // count(2)=2 < count(1)=10
        c.fill(2, f(2));
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
    }

    #[test]
    fn zero_capacity_never_admits() {
        let mut c = FeatureCache::new(0, 0);
        c.get(1);
        assert!(!c.wants(1));
        c.fill(1, f(1));
        assert!(c.get(1).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn clear_resident_keeps_counts() {
        let mut c = FeatureCache::new(4, 2);
        for _ in 0..3 {
            c.get(7);
        }
        c.fill(7, f(7));
        assert!(c.get(7).is_some());
        c.clear_resident();
        assert!(c.get(7).is_none());
        assert!(c.count(7) >= 3); // counts survive
        c.fill(7, f(7));
        assert!(c.get(7).is_some()); // immediate re-admission (already hot)
    }

    #[test]
    fn hit_ratio_math() {
        let mut c = FeatureCache::new(4, 0);
        c.get(1);
        c.fill(1, f(1));
        c.get(1);
        c.get(1);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn eviction_index_consistent_under_churn() {
        // stress: random access pattern must keep index and map in sync
        let mut c = FeatureCache::new(8, 1);
        let mut rng = crate::util::Rng::seed_from_u64(1);
        for _ in 0..5000 {
            let v = rng.gen_range(64) as u32;
            if c.get(v).is_none() {
                c.fill(v, f(v));
            }
        }
        assert!(c.len() <= 8);
        assert_eq!(c.evict_index.len(), c.resident.len());
        // every resident has a matching index entry
        for (&v, e) in &c.resident {
            assert!(c.evict_index.contains(&(e.key.0, e.key.1, v)), "node {v} key desync");
        }
    }
}
