//! Shared (`Send + Sync`) handles over the in-memory layer.
//!
//! The pipelined epoch executor runs the data-preparation stage on a
//! worker thread while the compute stage consumes the previous
//! hyperbatch, so the graph/feature buffers and the feature cache must be
//! usable through shared handles instead of `&mut` borrows. These
//! wrappers give the op layer interior mutability with the exact same
//! semantics as the underlying [`BufferPool`] / [`FeatureCache`]:
//! a single prepare stage drives them at a time (the executor never runs
//! two preparation stages concurrently), so the mutex is for memory
//! safety across the stage boundary, not for concurrency control.

use super::buffer_pool::{BufferPool, PoolStats};
use super::feature_cache::{FeatureCache, FeatureCacheStats};
use super::trace::{AccessLog, BeladySchedule};
use crate::storage::BlockId;
use std::sync::{Arc, Mutex, MutexGuard};

/// A cloneable, thread-safe handle to a [`BufferPool`].
pub struct SharedBufferPool<V> {
    inner: Arc<Mutex<BufferPool<V>>>,
}

impl<V> Clone for SharedBufferPool<V> {
    fn clone(&self) -> Self {
        SharedBufferPool { inner: self.inner.clone() }
    }
}

impl<V> SharedBufferPool<V> {
    pub fn new(capacity: usize) -> SharedBufferPool<V> {
        SharedBufferPool { inner: Arc::new(Mutex::new(BufferPool::new(capacity))) }
    }

    /// Lock for a compound operation (e.g. one sweep run). Never hold the
    /// guard across a call that re-enters the pool.
    pub fn lock(&self) -> MutexGuard<'_, BufferPool<V>> {
        self.inner.lock().expect("buffer pool poisoned")
    }

    pub fn capacity(&self) -> usize {
        self.lock().capacity()
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    pub fn stats(&self) -> PoolStats {
        self.lock().stats()
    }

    pub fn reset_stats(&self) {
        self.lock().reset_stats()
    }

    pub fn get(&self, b: BlockId) -> Option<Arc<V>> {
        self.lock().get(b)
    }

    pub fn peek(&self, b: BlockId) -> Option<Arc<V>> {
        self.lock().peek(b)
    }

    pub fn contains(&self, b: BlockId) -> bool {
        self.lock().contains(b)
    }

    pub fn insert(&self, b: BlockId, value: Arc<V>) -> Option<BlockId> {
        self.lock().insert(b, value)
    }

    /// Land a coalesced run read under one guard: `loaded` is the `(id,
    /// value)` pairs a run-shaped read delivered, `requested` the sorted
    /// block list that was asked for. Bridged-gap padding blocks (covered
    /// but not requested) are inserted *first* so that in a tight pool
    /// they — not the requested run about to be pinned and processed —
    /// become the LRU eviction victims. Already-resident blocks are left
    /// untouched (the "each block read once" invariant: a concurrent
    /// prefetch must not clobber a block another path just installed).
    pub fn insert_loaded(&self, requested: &[BlockId], loaded: Vec<(BlockId, V)>) {
        debug_assert!(requested.windows(2).all(|w| w[0] < w[1]), "requested must be sorted");
        let (req, pad): (Vec<_>, Vec<_>) =
            loaded.into_iter().partition(|(b, _)| requested.binary_search(b).is_ok());
        let mut guard = self.lock();
        for (b, v) in pad.into_iter().chain(req) {
            if !guard.contains(b) {
                guard.insert(b, Arc::new(v));
            }
        }
    }

    pub fn pin(&self, b: BlockId) {
        self.lock().pin(b)
    }

    pub fn unpin(&self, b: BlockId) {
        self.lock().unpin(b)
    }

    pub fn pinned(&self) -> usize {
        self.lock().pinned()
    }

    /// Start recording the pool's access trace (one lock; the per-access
    /// recording then rides the guards the sweeps already hold).
    pub fn start_recording(&self) {
        self.lock().start_recording()
    }

    /// Open hyperbatch `h` for the recorder and any installed schedule.
    pub fn begin_hyperbatch(&self, h: usize) {
        self.lock().begin_hyperbatch(h)
    }

    /// Drain the recorded access log.
    pub fn take_log(&self) -> AccessLog<BlockId> {
        self.lock().take_log()
    }

    /// Install a Belady eviction schedule (see [`super::trace`]).
    pub fn install_schedule(&self, schedule: BeladySchedule<BlockId>) {
        self.lock().install_schedule(schedule)
    }

    /// Drop partial traces and rewind the schedule (bench pass boundary).
    pub fn restart_trace(&self) {
        self.lock().restart_trace()
    }
}

/// A cloneable, thread-safe handle to a [`FeatureCache`].
#[derive(Clone)]
pub struct SharedFeatureCache {
    inner: Arc<Mutex<FeatureCache>>,
}

impl SharedFeatureCache {
    pub fn new(capacity: usize, threshold: u32) -> SharedFeatureCache {
        SharedFeatureCache { inner: Arc::new(Mutex::new(FeatureCache::new(capacity, threshold))) }
    }

    /// Lock for a compound operation (the gather sweep holds the guard for
    /// a pass instead of re-locking per node).
    pub fn lock(&self) -> MutexGuard<'_, FeatureCache> {
        self.inner.lock().expect("feature cache poisoned")
    }

    pub fn stats(&self) -> FeatureCacheStats {
        self.lock().stats()
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Zero counters and residents (epoch/bench counter resets). The
    /// recording flag and any installed Belady schedule survive — only the
    /// reactive/statistical state is wiped.
    pub fn reset(&self, capacity: usize, threshold: u32) {
        self.lock().reset(capacity, threshold);
    }

    /// Drop residents, keep access counts (epoch boundary).
    pub fn clear_resident(&self) {
        self.lock().clear_resident()
    }

    /// Start recording the cache's access trace.
    pub fn start_recording(&self) {
        self.lock().start_recording()
    }

    /// Open hyperbatch `h` for the recorder and any installed schedule.
    pub fn begin_hyperbatch(&self, h: usize) {
        self.lock().begin_hyperbatch(h)
    }

    /// Drain the recorded access log.
    pub fn take_log(&self) -> AccessLog<u32> {
        self.lock().take_log()
    }

    /// Install a Belady eviction schedule (see [`super::trace`]).
    pub fn install_schedule(&self, schedule: BeladySchedule<u32>) {
        self.lock().install_schedule(schedule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_pool_same_semantics() {
        let p: SharedBufferPool<u32> = SharedBufferPool::new(2);
        assert!(p.get(BlockId(1)).is_none());
        p.insert(BlockId(1), Arc::new(10));
        assert_eq!(*p.get(BlockId(1)).unwrap(), 10);
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        let clone = p.clone();
        clone.insert(BlockId(2), Arc::new(20));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn shared_pool_usable_across_threads() {
        let p: SharedBufferPool<u64> = SharedBufferPool::new(4);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                p.insert(BlockId(7), Arc::new(77));
            });
            h.join().unwrap();
        });
        assert_eq!(*p.get(BlockId(7)).unwrap(), 77);
    }

    #[test]
    fn insert_loaded_prefers_evicting_padding() {
        // capacity 2, a coalesced load of [5(pad), 6(req), 7(req)]: the
        // padding block must be the one that misses out, not the run
        let p: SharedBufferPool<u32> = SharedBufferPool::new(2);
        p.insert_loaded(&[BlockId(6), BlockId(7)], vec![
            (BlockId(5), 50),
            (BlockId(6), 60),
            (BlockId(7), 70),
        ]);
        assert!(p.contains(BlockId(6)) && p.contains(BlockId(7)));
        assert!(!p.contains(BlockId(5)), "padding should be the eviction victim");
        // already-resident blocks are not clobbered
        p.insert_loaded(&[BlockId(6)], vec![(BlockId(6), 99)]);
        assert_eq!(*p.get(BlockId(6)).unwrap(), 60);
    }

    #[test]
    fn shared_cache_reset() {
        let c = SharedFeatureCache::new(4, 0);
        {
            let mut g = c.lock();
            g.get(1);
            g.fill(1, vec![1.0]);
        }
        assert_eq!(c.len(), 1);
        c.reset(4, 0);
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats().hits + c.stats().misses, 0);
    }
}
