//! Adaptive runtime controller vs the static `pipeline_depth x
//! gap_blocks` grid (ISSUE 8 acceptance): a dense sweep driven for a few
//! epochs with `adaptive.enabled = true` and `io.gap_blocks = "auto"`
//! must reach a measured-epoch prepare **storage** time no worse than the
//! best static grid configuration — with bit-identical loss, since the
//! controller only reshapes requests and schedules, never training data.
//!
//! `cargo bench --bench adaptive_sweep`
//!
//! Set `AGNES_ADAPTIVE_TINY=1` for the CI smoke configuration (tiny
//! dataset, seconds instead of minutes). Either way the bench emits
//! `target/bench_results/BENCH_adaptive.json` with the full grid and the
//! adaptive run's decisions, so the perf trajectory accumulates across
//! builds and the bench-regression gate can pin the storage seconds and
//! loss bits.

use agnes::config::{AgnesConfig, GapBlocks};
use agnes::coordinator::{EpochResult, ModeledCompute};
use agnes::util::bench::{bench_config, secs, Table, MODELED_COMPUTE_NS};
use agnes::util::json::Json;
use agnes::AgnesRunner;

/// Epochs per run: epoch 0 observes (and the controller decides at its
/// boundary), epoch 1 runs adapted and washes the observation epoch's
/// residual buffer state out, epoch 2 is measured.
const EPOCHS: usize = 3;
const DEPTHS: [usize; 2] = [1, 2];
/// The full gap-candidate set the controller prices (0 plus every power
/// of two up to the validation cap), so the adaptive choice always has an
/// exact static twin in the grid.
const GAPS: [u32; 12] = [0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];

fn tiny_mode() -> bool {
    std::env::var("AGNES_ADAPTIVE_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The dense-sweep workload of the fig11 family, with buffers deliberately
/// smaller than the dataset so every epoch pays real storage I/O (a fully
/// resident sweep would leave the controller nothing to adapt).
fn dense_config(tiny: bool) -> AgnesConfig {
    let mut c = if tiny { bench_config("tiny", 1.0) } else { bench_config("ig", 0.5) };
    c.dataset.feature_dim = 256;
    c.io.block_size = 4 << 10;
    c.io.max_request_bytes = 256 << 10;
    c.memory.graph_buffer_bytes = 512 << 10;
    c.memory.feature_buffer_bytes = 4 << 20;
    c.memory.feature_cache_entries = 1024;
    c.train.minibatch_size = 64;
    c.train.hyperbatch_size = 32;
    c.train.target_fraction = 1.0;
    c
}

fn run_epochs(c: &AgnesConfig) -> anyhow::Result<Vec<EpochResult>> {
    let mut runner = AgnesRunner::open(c.clone())?;
    let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
    (0..EPOCHS).map(|e| runner.run_epoch(e, &mut compute)).collect()
}

/// Measured-epoch prepare storage time: the simulated device nanoseconds
/// charged while sampling + gathering, per-epoch by construction (unlike
/// the cumulative device counters, which span the whole runner).
fn prep_storage_ns(r: &EpochResult) -> u64 {
    r.metrics.sample_io_ns + r.metrics.gather_io_ns
}

fn loss_bits(r: &EpochResult) -> String {
    format!("0x{:08x}", r.mean_loss.to_bits())
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();

    // ---- the static grid ----------------------------------------------
    println!("=== Adaptive controller vs static grid: dense sweep (AGNES) ===\n");
    let mut t = Table::new(
        "adaptive_grid",
        &["depth", "gap_blocks", "prep_storage_s", "loss_bits"],
    );
    let mut grid_json: Vec<Json> = Vec::new();
    let mut best_ns = u64::MAX;
    let mut losses: Vec<u32> = Vec::new();
    for &depth in &DEPTHS {
        for &gap in &GAPS {
            let mut c = dense_config(tiny);
            c.train.pipeline_depth = depth;
            c.io.gap_blocks = GapBlocks::Fixed(gap);
            let runs = run_epochs(&c)?;
            let last = runs.last().unwrap();
            let ns = prep_storage_ns(last);
            best_ns = best_ns.min(ns);
            losses.push(last.mean_loss.to_bits());
            t.row(vec![
                depth.to_string(),
                gap.to_string(),
                secs(ns),
                loss_bits(last),
            ]);
            grid_json.push(Json::obj(vec![
                ("depth", Json::num(depth as f64)),
                ("gap", Json::num(gap)),
                ("prep_storage_s", Json::num(ns as f64 * 1e-9)),
                ("loss_bits", Json::str(loss_bits(last))),
            ]));
        }
    }

    // ---- the adaptive run ---------------------------------------------
    let mut c = dense_config(tiny);
    c.train.pipeline_depth = *DEPTHS.iter().max().unwrap();
    c.io.gap_blocks = GapBlocks::Auto;
    c.adaptive.enabled = true;
    let runs = run_epochs(&c)?;
    let last = runs.last().unwrap();
    let adaptive_ns = prep_storage_ns(last);
    losses.push(last.mean_loss.to_bits());
    t.row(vec![
        format!("{} (adaptive)", c.train.pipeline_depth),
        format!("auto->{}", last.metrics.effective_gap_blocks),
        secs(adaptive_ns),
        loss_bits(last),
    ]);
    t.finish();

    let mut decisions: Vec<String> = Vec::new();
    for (e, r) in runs.iter().enumerate() {
        if let Some(line) = r.metrics.controller.epoch_summary(e as u32) {
            println!("{line}");
            decisions.push(line);
        }
    }
    println!(
        "\nadaptive {} vs best static {} (grid of {} configs)",
        secs(adaptive_ns),
        secs(best_ns),
        DEPTHS.len() * GAPS.len(),
    );

    // ---- the acceptance assertions ------------------------------------
    // The spec-derived "auto" seed is never a power of two on this block
    // size, while the controller only picks histogram bucket bounds — so
    // the observation epoch must always produce at least one decision.
    anyhow::ensure!(
        !runs[0].metrics.controller.decisions.is_empty(),
        "adaptive observation epoch logged no controller decisions"
    );
    // The measured epoch runs at the modeled-optimal gap candidate, whose
    // exact static twin is in the grid; the 2% slack only absorbs the
    // observation epoch's residual buffer-pool state (gap padding warms
    // the pool, so the adapted run enters the measured epoch with a
    // slightly different tail of resident blocks than its static twin).
    anyhow::ensure!(
        adaptive_ns <= best_ns + best_ns / 50,
        "adaptive measured epoch ({adaptive_ns} ns) slower than the best \
         static grid config ({best_ns} ns)"
    );
    // Neither the schedule, nor the gap budget, nor the controller itself
    // may ever change the training outcome.
    let first = losses[0];
    anyhow::ensure!(
        losses.iter().all(|&b| b == first),
        "loss diverged across the grid/adaptive runs"
    );

    // machine-readable perf record for the trajectory
    let report = Json::obj(vec![
        ("bench", Json::str("adaptive_sweep")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("grid", Json::arr(grid_json)),
        (
            "adaptive",
            Json::obj(vec![
                ("prep_storage_s", Json::num(adaptive_ns as f64 * 1e-9)),
                ("best_static_prep_storage_s", Json::num(best_ns as f64 * 1e-9)),
                ("effective_gap_blocks", Json::num(last.metrics.effective_gap_blocks as f64)),
                ("pipeline_depth", Json::num(last.metrics.pipeline_depth as f64)),
                ("loss_bits", Json::str(loss_bits(last))),
                ("decisions", Json::arr(decisions.iter().map(|d| Json::str(d.clone())))),
            ]),
        ),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_adaptive.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_adaptive.json");

    println!(
        "\nShape check vs paper: the self-tuning controller reaches the \
         best static (pipeline_depth x gap_blocks) grid configuration's \
         prepare storage time from the live trace alone — no grid search — \
         while the loss stays bit-identical across every schedule, budget, \
         and the adaptive run itself."
    );
    Ok(())
}
