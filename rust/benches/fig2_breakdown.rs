//! Figure 2 — the motivating observation: (a) data preparation dominates
//! the execution time of the state-of-the-art storage-based methods
//! (Ginex, GNNDrive); (b) their storage I/Os are overwhelmingly small,
//! while AGNES's run-coalescing planner merges contiguous block runs into
//! large sequential requests that land in the `<=1MB`/`>1MB` classes;
//! (c) small I/Os leave the compute device idle (utilization proxy:
//! compute fraction of total time).
//!
//! `cargo bench --bench fig2_breakdown`
//!
//! Set `AGNES_FIG2_TINY=1` for the CI smoke configuration (tiny dataset,
//! 4 KiB blocks, seconds instead of minutes). Either way the bench emits
//! `target/bench_results/BENCH_fig2.json` with the per-system I/O-size
//! distribution and the coalescing-on/off preparation times, so the perf
//! trajectory accumulates across builds.

use agnes::config::{AgnesConfig, GnnModel};
use agnes::coordinator::{EpochResult, ModeledCompute, NullCompute};
use agnes::metrics::RunMetrics;
use agnes::storage::device::IoClass;
use agnes::storage::plan::{plan_hist_bound, PlanHistogram, PLAN_HIST_BUCKETS};
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};
use agnes::util::json::Json;

fn tiny_mode() -> bool {
    std::env::var("AGNES_FIG2_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The workload configuration: paper-shaped at bench scale, or the CI
/// smoke shape (tiny dataset, 4 KiB blocks so coalescing has many blocks
/// to merge) under `AGNES_FIG2_TINY=1`.
fn base_config(tiny: bool, ds: &str, scale: f64) -> AgnesConfig {
    if !tiny {
        return bench_config(ds, scale);
    }
    let mut c = bench_config("tiny", 1.0);
    c.dataset.feature_dim = 64;
    c.io.block_size = 4 << 10;
    c.memory.graph_buffer_bytes = 1 << 20;
    c.memory.feature_buffer_bytes = 1 << 20;
    c.memory.feature_cache_entries = 1024;
    c.train.minibatch_size = 32;
    c.train.hyperbatch_size = 4;
    c.train.target_fraction = 0.2;
    c
}

fn hist_row(label: String, h: [u64; 5], total: u64) -> Vec<String> {
    let pct = |i: usize| format!("{:.1}%", 100.0 * h[i] as f64 / total.max(1) as f64);
    vec![label, pct(0), pct(1), pct(2), pct(3), pct(4), total.to_string()]
}

fn system_json(system: &str, ds: &str, model: &str, m: &RunMetrics, compute_ns: u64) -> Json {
    let hist = Json::obj(
        IoClass::all()
            .iter()
            .enumerate()
            .map(|(i, c)| (c.label(), Json::num(m.device.size_hist[i] as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("system", Json::str(system)),
        ("dataset", Json::str(ds)),
        ("model", Json::str(model)),
        ("prep_s", Json::num(m.prep_ns() as f64 * 1e-9)),
        ("compute_s", Json::num(compute_ns as f64 * 1e-9)),
        ("span_s", Json::num(m.span_ns() as f64 * 1e-9)),
        ("requests", Json::num(m.device.num_requests as f64)),
        ("total_bytes", Json::num(m.device.total_bytes as f64)),
        ("mean_request_bytes", Json::num(m.mean_request_bytes())),
        ("io_runs", Json::num(m.io_runs as f64)),
        ("mean_blocks_per_run", Json::num(m.mean_blocks_per_run())),
        ("size_hist", hist),
    ])
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();
    let datasets: &[(&str, f64)] =
        if tiny { &[("tiny", 1.0)] } else { &[("tw", 0.1), ("pa", 0.1), ("fr", 0.05)] };
    let systems: &[&str] = &["ginex", "gnndrive", "agnes"];
    let models: &[GnnModel] =
        if tiny { &[GnnModel::Sage] } else { &[GnnModel::Gcn, GnnModel::Sage] };

    println!("=== Figure 2(a): execution-time breakdown (prep vs compute) ===\n");
    let mut t = Table::new(
        "fig2a_breakdown",
        &["system", "model", "dataset", "prep_s", "compute_s", "prep_pct"],
    );
    let mut util = Table::new(
        "fig2c_utilization",
        &["system", "model", "dataset", "compute_util_pct"],
    );
    let mut hist: Vec<(String, [u64; 5], u64)> = Vec::new();
    let mut json_systems: Vec<Json> = Vec::new();
    for &(ds, scale) in datasets {
        for &system in systems {
            for &model in models {
                let mut config = base_config(tiny, ds, scale);
                config.train.model = model;
                let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
                let r = run_epoch_by_name(system, &config, &mut compute)?;
                let m = &r.metrics;
                let prep = m.prep_ns();
                let comp = compute.simulated_ns;
                let total = prep + comp;
                t.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    secs(prep),
                    secs(comp),
                    format!("{:.1}", 100.0 * prep as f64 / total.max(1) as f64),
                ]);
                util.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    format!("{:.1}", 100.0 * comp as f64 / total.max(1) as f64),
                ]);
                if model == GnnModel::Sage {
                    hist.push((
                        format!("{system}/{ds}"),
                        m.device.size_hist,
                        m.device.num_requests,
                    ));
                    json_systems.push(system_json(system, ds, model.name(), m, comp));
                }
            }
        }
    }
    t.finish();

    println!("\n=== Figure 2(b): storage I/O size distribution (SAGE) ===\n");
    let mut t2 = Table::new(
        "fig2b_io_sizes",
        &["system/dataset", "<=4KB", "<=64KB", "<=256KB", "<=1MB", ">1MB", "total"],
    );
    for (label, h, total) in hist {
        t2.row(hist_row(label, h, total));
    }
    t2.finish();

    println!("\n=== Figure 2(c): compute utilization ===\n");
    util.finish();

    // The tentpole mechanism, isolated: the same AGNES epoch with the
    // run-coalescing planner on (default 1 MiB requests) vs off
    // (max_request_bytes = block_size, i.e. the per-block pre-coalescing
    // build). Same blocks, same outputs — only the request shape changes,
    // so the simulated preparation time difference is pure coalescing win.
    println!("\n=== Run coalescing: request shape and preparation time (AGNES, SAGE) ===\n");
    let mut t4 = Table::new(
        "fig2e_coalescing",
        &[
            "dataset",
            "planner",
            "requests",
            "mean_req_bytes",
            "blocks_per_run",
            "prep_s",
        ],
    );
    let (co_ds, co_scale) = datasets[0];
    let mut coalescing_json: Vec<(&str, Json)> = Vec::new();
    let mut run_coalescing = |on: bool| -> anyhow::Result<EpochResult> {
        let mut config = base_config(tiny, co_ds, co_scale);
        if !on {
            config.io.max_request_bytes = config.io.block_size;
        }
        let r = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
        let m = &r.metrics;
        t4.row(vec![
            co_ds.to_uppercase(),
            if on { "coalescing".into() } else { "per-block".into() },
            m.device.num_requests.to_string(),
            format!("{:.0}", m.mean_request_bytes()),
            format!("{:.1}", m.mean_blocks_per_run()),
            secs(m.prep_ns()),
        ]);
        Ok(r)
    };
    let on = run_coalescing(true)?;
    let off = run_coalescing(false)?;
    t4.finish();
    let (on_m, off_m) = (&on.metrics, &off.metrics);
    coalescing_json.push(("dataset", Json::str(co_ds)));
    coalescing_json.push(("on_prep_s", Json::num(on_m.prep_ns() as f64 * 1e-9)));
    coalescing_json.push(("off_prep_s", Json::num(off_m.prep_ns() as f64 * 1e-9)));
    coalescing_json.push(("on_requests", Json::num(on_m.device.num_requests as f64)));
    coalescing_json.push(("off_requests", Json::num(off_m.device.num_requests as f64)));
    coalescing_json.push(("on_mean_request_bytes", Json::num(on_m.mean_request_bytes())));
    coalescing_json.push(("off_mean_request_bytes", Json::num(off_m.mean_request_bytes())));
    coalescing_json.push(("on_mean_blocks_per_run", Json::num(on_m.mean_blocks_per_run())));
    println!(
        "\nCoalescing: {} -> {} requests, mean {} -> {} bytes/request, prep {} -> {}",
        off_m.device.num_requests,
        on_m.device.num_requests,
        off_m.mean_request_bytes() as u64,
        on_m.mean_request_bytes() as u64,
        secs(off_m.prep_ns()),
        secs(on_m.prep_ns()),
    );

    // The planner's observed distributions behind that win: hole sizes
    // between requested blocks (what gap bridging can buy) and emitted
    // run lengths (what coalescing produced). This is the exact input the
    // adaptive controller prices `io.gap_blocks = "auto"` from.
    println!("\n=== Planner distributions: hole sizes and run lengths (AGNES) ===\n");
    let mut t5 = Table::new(
        "fig2f_plan_histogram",
        &["size<=blocks", "holes", "hole_blocks", "runs", "run_blocks"],
    );
    let plan = &on_m.plan;
    for i in 0..PLAN_HIST_BUCKETS {
        if plan.holes.counts[i] == 0 && plan.runs.counts[i] == 0 {
            continue;
        }
        t5.row(vec![
            plan_hist_bound(i).to_string(),
            plan.holes.counts[i].to_string(),
            plan.holes.blocks[i].to_string(),
            plan.runs.counts[i].to_string(),
            plan.runs.blocks[i].to_string(),
        ]);
    }
    t5.finish();
    println!(
        "\nPlanner saw {} holes ({} blocks) and emitted {} runs ({} blocks)",
        plan.holes.total_count(),
        plan.holes.total_blocks(),
        plan.runs.total_count(),
        plan.runs.total_blocks(),
    );
    let hist_json = |h: &PlanHistogram| {
        Json::arr((0..PLAN_HIST_BUCKETS).map(|i| Json::num(h.counts[i] as f64)))
    };
    coalescing_json.push((
        "plan_hist_bounds",
        Json::arr((0..PLAN_HIST_BUCKETS).map(|i| Json::num(plan_hist_bound(i)))),
    ));
    coalescing_json.push(("hole_hist", hist_json(&plan.holes)));
    coalescing_json.push(("run_hist", hist_json(&plan.runs)));

    // AGNES's answer to 2(a): the staged pipeline executor hides data
    // preparation behind compute. Same config, same work — only the
    // schedule changes, so work_s is constant while span_s shrinks. The
    // three-stage schedule splits preparation into sample/gather workers,
    // so the per-stage columns show where the remaining span lives and
    // stall/backpressure name the bottleneck stage. The slash-separated
    // values follow each row's own schedule: two-stage rows are
    // prepare/compute, three-stage rows are sample/gather/compute.
    println!("\n=== Staged pipeline executor: per-stage overlap (AGNES) ===\n");
    let mut t3 = Table::new(
        "fig2d_pipeline_overlap",
        &[
            "mode",
            "depth",
            "work_s",
            "span_s",
            "overlap_pct",
            "sample_s",
            "gather_s",
            "compute_s",
            "stall_ms",
            "backpressure_ms",
        ],
    );
    let per_stage_ms = |v: &[u64]| {
        if v.is_empty() {
            "-".to_string()
        } else {
            v.iter().map(|&x| format!("{:.1}", x as f64 / 1e6)).collect::<Vec<_>>().join("/")
        }
    };
    // stream several hyperbatches so the pipeline actually fills
    let pipeline_config = || -> AgnesConfig {
        let mut c = base_config(tiny, co_ds, co_scale);
        c.train.target_fraction = 0.5;
        c.train.hyperbatch_size = 4;
        c
    };
    // calibrate the modeled compute cost to ~60% of AGNES's measured
    // per-minibatch preparation on this config: preparation stays the
    // moderate bottleneck, which is the regime where splitting it into
    // sample/gather workers pays (under a fully compute-bound schedule
    // both pipelined modes hide all of preparation and tie)
    let calib_ns = {
        let mut config = pipeline_config();
        config.train.pipeline_depth = 1;
        let r = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
        (r.metrics.prep_ns() as f64 * 0.6 / r.metrics.minibatches.max(1) as f64) as u64
    };
    let mut overlaps: Vec<(&str, f64)> = Vec::new();
    for (mode, depth, stages) in
        [("sequential", 1usize, 1usize), ("two-stage", 4, 1), ("three-stage", 4, 2)]
    {
        let mut config = pipeline_config();
        config.train.pipeline_depth = depth;
        config.train.prepare_stages = stages;
        let mut compute = ModeledCompute::new(calib_ns);
        let r = run_epoch_by_name("agnes", &config, &mut compute)?;
        let m = &r.metrics;
        t3.row(vec![
            mode.into(),
            depth.to_string(),
            secs(m.total_ns()),
            secs(m.span_ns()),
            format!("{:.1}", m.overlap_fraction() * 100.0),
            secs(m.sample_stage_ns()),
            secs(m.gather_stage_ns()),
            secs(m.compute_ns()),
            per_stage_ms(&m.stage_stall_ns),
            per_stage_ms(&m.stage_backpressure_ns),
        ]);
        overlaps.push((mode, m.overlap_fraction()));
    }
    t3.finish();
    println!(
        "\nOverlap by schedule: {}",
        overlaps
            .iter()
            .map(|(m, o)| format!("{m}={:.1}%", o * 100.0))
            .collect::<Vec<_>>()
            .join("  ")
    );

    // machine-readable perf record for the trajectory
    let report = Json::obj(vec![
        ("bench", Json::str("fig2_breakdown")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("systems", Json::arr(json_systems)),
        ("coalescing", Json::obj(coalescing_json)),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_fig2.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_fig2.json");

    println!(
        "\nShape check vs paper: prep dominates for the baselines (up to \
         ~96%), their I/O distribution mass sits in the smallest class \
         while AGNES's coalesced runs land in the large classes with a \
         lower preparation time than the per-block ablation, with \
         pipeline_depth >= 2 the epoch span drops below the sequential \
         prep+compute sum (preparation hidden behind computation), and \
         the three-stage schedule overlaps strictly more than the \
         two-stage schedule (sampling of k+2 hides under gathering of \
         k+1 under compute of k)."
    );
    Ok(())
}
