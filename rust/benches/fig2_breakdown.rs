//! Figure 2 — the motivating observation: (a) data preparation dominates
//! the execution time of the state-of-the-art storage-based methods
//! (Ginex, GNNDrive); (b) their storage I/Os are overwhelmingly small;
//! (c) small I/Os leave the compute device idle (utilization proxy:
//! compute fraction of total time).
//!
//! `cargo bench --bench fig2_breakdown`

use agnes::config::{AgnesConfig, GnnModel};
use agnes::coordinator::{ModeledCompute, NullCompute};
use agnes::storage::device::IoClass;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};

const DATASETS: &[(&str, f64)] = &[("tw", 0.1), ("pa", 0.1), ("fr", 0.05)];
const SYSTEMS: &[&str] = &["ginex", "gnndrive"];
const MODELS: &[GnnModel] = &[GnnModel::Gcn, GnnModel::Sage];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 2(a): execution-time breakdown (prep vs compute) ===\n");
    let mut t = Table::new(
        "fig2a_breakdown",
        &["system", "model", "dataset", "prep_s", "compute_s", "prep_pct"],
    );
    let mut util = Table::new(
        "fig2c_utilization",
        &["system", "model", "dataset", "compute_util_pct"],
    );
    let mut hist: Vec<(String, [u64; 5], u64)> = Vec::new();
    for &(ds, scale) in DATASETS {
        for &system in SYSTEMS {
            for &model in MODELS {
                let mut config = bench_config(ds, scale);
                config.train.model = model;
                let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
                let r = run_epoch_by_name(system, &config, &mut compute)?;
                let m = &r.metrics;
                let prep = m.prep_ns();
                let comp = compute.simulated_ns;
                let total = prep + comp;
                t.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    secs(prep),
                    secs(comp),
                    format!("{:.1}", 100.0 * prep as f64 / total.max(1) as f64),
                ]);
                util.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    format!("{:.1}", 100.0 * comp as f64 / total.max(1) as f64),
                ]);
                if model == GnnModel::Sage {
                    hist.push((format!("{system}/{ds}"), m.device.size_hist, m.device.num_requests));
                }
            }
        }
    }
    t.finish();

    println!("\n=== Figure 2(b): storage I/O size distribution (SAGE) ===\n");
    let mut t2 = Table::new(
        "fig2b_io_sizes",
        &["system/dataset", "<=4KB", "<=64KB", "<=256KB", "<=1MB", ">1MB", "total"],
    );
    for (label, h, total) in hist {
        let pct = |i: usize| format!("{:.1}%", 100.0 * h[i] as f64 / total.max(1) as f64);
        t2.row(vec![label, pct(0), pct(1), pct(2), pct(3), pct(4), total.to_string()]);
    }
    t2.finish();
    let _ = IoClass::all();

    println!("\n=== Figure 2(c): compute utilization ===\n");
    util.finish();

    // AGNES's answer to 2(a): the staged pipeline executor hides data
    // preparation behind compute. Same config, same work — only the
    // schedule changes, so work_s is constant while span_s shrinks. The
    // three-stage schedule splits preparation into sample/gather workers,
    // so the per-stage columns show where the remaining span lives and
    // stall/backpressure name the bottleneck stage. The slash-separated
    // values follow each row's own schedule: two-stage rows are
    // prepare/compute, three-stage rows are sample/gather/compute.
    println!("\n=== Staged pipeline executor: per-stage overlap (AGNES, TW) ===\n");
    let mut t3 = Table::new(
        "fig2d_pipeline_overlap",
        &[
            "mode",
            "depth",
            "work_s",
            "span_s",
            "overlap_pct",
            "sample_s",
            "gather_s",
            "compute_s",
            "stall_ms",
            "backpressure_ms",
        ],
    );
    let per_stage_ms = |v: &[u64]| {
        if v.is_empty() {
            "-".to_string()
        } else {
            v.iter().map(|&x| format!("{:.1}", x as f64 / 1e6)).collect::<Vec<_>>().join("/")
        }
    };
    // stream several hyperbatches so the pipeline actually fills
    let pipeline_config = || -> AgnesConfig {
        let mut c = bench_config("tw", 0.1);
        c.train.target_fraction = 0.5;
        c.train.hyperbatch_size = 4;
        c
    };
    // calibrate the modeled compute cost to ~60% of AGNES's measured
    // per-minibatch preparation on this config: preparation stays the
    // moderate bottleneck, which is the regime where splitting it into
    // sample/gather workers pays (under a fully compute-bound schedule
    // both pipelined modes hide all of preparation and tie)
    let calib_ns = {
        let mut config = pipeline_config();
        config.train.pipeline_depth = 1;
        let r = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
        (r.metrics.prep_ns() as f64 * 0.6 / r.metrics.minibatches.max(1) as f64) as u64
    };
    let mut overlaps: Vec<(&str, f64)> = Vec::new();
    for (mode, depth, stages) in
        [("sequential", 1usize, 1usize), ("two-stage", 4, 1), ("three-stage", 4, 2)]
    {
        let mut config = pipeline_config();
        config.train.pipeline_depth = depth;
        config.train.prepare_stages = stages;
        let mut compute = ModeledCompute::new(calib_ns);
        let r = run_epoch_by_name("agnes", &config, &mut compute)?;
        let m = &r.metrics;
        t3.row(vec![
            mode.into(),
            depth.to_string(),
            secs(m.total_ns()),
            secs(m.span_ns()),
            format!("{:.1}", m.overlap_fraction() * 100.0),
            secs(m.sample_stage_ns()),
            secs(m.gather_stage_ns()),
            secs(m.compute_ns()),
            per_stage_ms(&m.stage_stall_ns),
            per_stage_ms(&m.stage_backpressure_ns),
        ]);
        overlaps.push((mode, m.overlap_fraction()));
    }
    t3.finish();
    println!(
        "\nOverlap by schedule: {}",
        overlaps
            .iter()
            .map(|(m, o)| format!("{m}={:.1}%", o * 100.0))
            .collect::<Vec<_>>()
            .join("  ")
    );

    println!(
        "\nShape check vs paper: prep dominates (up to ~96%), the I/O \
         distribution mass sits in the smallest class, with \
         pipeline_depth >= 2 the epoch span drops below the sequential \
         prep+compute sum (preparation hidden behind computation), and \
         the three-stage schedule overlaps strictly more than the \
         two-stage schedule (sampling of k+2 hides under gathering of \
         k+1 under compute of k)."
    );
    Ok(())
}
