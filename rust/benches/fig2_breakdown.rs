//! Figure 2 — the motivating observation: (a) data preparation dominates
//! the execution time of the state-of-the-art storage-based methods
//! (Ginex, GNNDrive); (b) their storage I/Os are overwhelmingly small;
//! (c) small I/Os leave the compute device idle (utilization proxy:
//! compute fraction of total time).
//!
//! `cargo bench --bench fig2_breakdown`

use agnes::config::GnnModel;
use agnes::coordinator::ModeledCompute;
use agnes::storage::device::IoClass;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};

const DATASETS: &[(&str, f64)] = &[("tw", 0.1), ("pa", 0.1), ("fr", 0.05)];
const SYSTEMS: &[&str] = &["ginex", "gnndrive"];
const MODELS: &[GnnModel] = &[GnnModel::Gcn, GnnModel::Sage];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 2(a): execution-time breakdown (prep vs compute) ===\n");
    let mut t = Table::new(
        "fig2a_breakdown",
        &["system", "model", "dataset", "prep_s", "compute_s", "prep_pct"],
    );
    let mut util = Table::new(
        "fig2c_utilization",
        &["system", "model", "dataset", "compute_util_pct"],
    );
    let mut hist: Vec<(String, [u64; 5], u64)> = Vec::new();
    for &(ds, scale) in DATASETS {
        for &system in SYSTEMS {
            for &model in MODELS {
                let mut config = bench_config(ds, scale);
                config.train.model = model;
                let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
                let r = run_epoch_by_name(system, &config, &mut compute)?;
                let m = &r.metrics;
                let prep = m.prep_ns();
                let comp = compute.simulated_ns;
                let total = prep + comp;
                t.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    secs(prep),
                    secs(comp),
                    format!("{:.1}", 100.0 * prep as f64 / total.max(1) as f64),
                ]);
                util.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    format!("{:.1}", 100.0 * comp as f64 / total.max(1) as f64),
                ]);
                if model == GnnModel::Sage {
                    hist.push((format!("{system}/{ds}"), m.device.size_hist, m.device.num_requests));
                }
            }
        }
    }
    t.finish();

    println!("\n=== Figure 2(b): storage I/O size distribution (SAGE) ===\n");
    let mut t2 = Table::new(
        "fig2b_io_sizes",
        &["system/dataset", "<=4KB", "<=64KB", "<=256KB", "<=1MB", ">1MB", "total"],
    );
    for (label, h, total) in hist {
        let pct = |i: usize| format!("{:.1}%", 100.0 * h[i] as f64 / total.max(1) as f64);
        t2.row(vec![label, pct(0), pct(1), pct(2), pct(3), pct(4), total.to_string()]);
    }
    t2.finish();
    let _ = IoClass::all();

    println!("\n=== Figure 2(c): compute utilization ===\n");
    util.finish();

    // AGNES's answer to 2(a): the staged pipeline executor hides data
    // preparation behind compute. Same config, same work — only the
    // schedule changes, so work_s is constant while span_s shrinks.
    println!("\n=== Pipelined epoch executor: prepare/compute overlap (AGNES, TW) ===\n");
    let mut t3 = Table::new(
        "fig2d_pipeline_overlap",
        &["mode", "depth", "work_s", "span_s", "overlap_pct", "stall_ms", "backpressure_ms"],
    );
    for depth in [1usize, 2, 4] {
        let mut config = bench_config("tw", 0.1);
        config.train.pipeline_depth = depth;
        let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
        let r = run_epoch_by_name("agnes", &config, &mut compute)?;
        let m = &r.metrics;
        t3.row(vec![
            (if depth <= 1 { "sequential" } else { "pipelined" }).into(),
            depth.to_string(),
            secs(m.total_ns()),
            secs(m.span_ns()),
            format!("{:.1}", m.overlap_fraction() * 100.0),
            format!("{:.1}", m.prep_stall_ns as f64 / 1e6),
            format!("{:.1}", m.prep_backpressure_ns as f64 / 1e6),
        ]);
    }
    t3.finish();

    println!(
        "\nShape check vs paper: prep dominates (up to ~96%), the I/O \
         distribution mass sits in the smallest class, and with \
         pipeline_depth >= 2 the epoch span drops below the sequential \
         prep+compute sum (preparation hidden behind computation)."
    );
    Ok(())
}
