//! Figure 2 — the motivating observation: (a) data preparation dominates
//! the execution time of the state-of-the-art storage-based methods
//! (Ginex, GNNDrive); (b) their storage I/Os are overwhelmingly small;
//! (c) small I/Os leave the compute device idle (utilization proxy:
//! compute fraction of total time).
//!
//! `cargo bench --bench fig2_breakdown`

use agnes::config::GnnModel;
use agnes::coordinator::ModeledCompute;
use agnes::storage::device::IoClass;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};

const DATASETS: &[(&str, f64)] = &[("tw", 0.1), ("pa", 0.1), ("fr", 0.05)];
const SYSTEMS: &[&str] = &["ginex", "gnndrive"];
const MODELS: &[GnnModel] = &[GnnModel::Gcn, GnnModel::Sage];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 2(a): execution-time breakdown (prep vs compute) ===\n");
    let mut t = Table::new(
        "fig2a_breakdown",
        &["system", "model", "dataset", "prep_s", "compute_s", "prep_pct"],
    );
    let mut util = Table::new(
        "fig2c_utilization",
        &["system", "model", "dataset", "compute_util_pct"],
    );
    let mut hist: Vec<(String, [u64; 5], u64)> = Vec::new();
    for &(ds, scale) in DATASETS {
        for &system in SYSTEMS {
            for &model in MODELS {
                let mut config = bench_config(ds, scale);
                config.train.model = model;
                let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
                let r = run_epoch_by_name(system, &config, &mut compute)?;
                let m = &r.metrics;
                let prep = m.prep_ns();
                let comp = compute.simulated_ns;
                let total = prep + comp;
                t.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    secs(prep),
                    secs(comp),
                    format!("{:.1}", 100.0 * prep as f64 / total.max(1) as f64),
                ]);
                util.row(vec![
                    system.into(),
                    model.name().into(),
                    ds.to_uppercase(),
                    format!("{:.1}", 100.0 * comp as f64 / total.max(1) as f64),
                ]);
                if model == GnnModel::Sage {
                    hist.push((format!("{system}/{ds}"), m.device.size_hist, m.device.num_requests));
                }
            }
        }
    }
    t.finish();

    println!("\n=== Figure 2(b): storage I/O size distribution (SAGE) ===\n");
    let mut t2 = Table::new(
        "fig2b_io_sizes",
        &["system/dataset", "<=4KB", "<=64KB", "<=256KB", "<=1MB", ">1MB", "total"],
    );
    for (label, h, total) in hist {
        let pct = |i: usize| format!("{:.1}%", 100.0 * h[i] as f64 / total.max(1) as f64);
        t2.row(vec![label, pct(0), pct(1), pct(2), pct(3), pct(4), total.to_string()]);
    }
    t2.finish();
    let _ = IoClass::all();

    println!("\n=== Figure 2(c): compute utilization ===\n");
    util.finish();
    println!(
        "\nShape check vs paper: prep dominates (up to ~96%), and the I/O \
         distribution mass sits in the smallest class."
    );
    Ok(())
}
