//! Figure 9 — block-size and hyperbatch-size sweeps on YH (the largest
//! dataset): execution time and storage I/O count. The paper finds the
//! sweet spot at 1024 KB blocks (scaled here) and hyperbatch ≥ 1024
//! (scaled to the epoch's minibatch count).
//!
//! `cargo bench --bench fig9_sweep`

use agnes::coordinator::NullCompute;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};

fn main() -> anyhow::Result<()> {
    // block sizes scaled /4 from the paper's 64KB..4096KB (graphs are
    // ~1000x smaller; keep the sweep 16KB..1024KB so blocks stay a
    // meaningful fraction of the store)
    println!("=== Figure 9(a): block-size sweep (YH) ===\n");
    let mut t = Table::new("fig9a_block_size", &["block_kb", "exec_s", "storage_ios"]);
    for block_kb in [4usize, 16, 64, 256, 1024] {
        let mut config = bench_config("yh", 0.01);
        config.io.block_size = block_kb << 10;
        // buffers scale with the (scaled) dataset, not the block size:
        // fixed byte budget so large blocks mean few frames, as on the
        // paper's testbed
        config.memory.graph_buffer_bytes = 512 << 10;
        config.memory.feature_buffer_bytes = 512 << 10;
        config.memory.feature_cache_entries = 1024;
        // sparse per-sweep working set: the waste term (unnecessary data
        // per block) shows on the right of the sweep, the per-request
        // latency term on the left — the paper's U-shape
        config.train.minibatch_size = 50;
        config.train.target_fraction = 0.04;
        let r = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
        t.row(vec![
            block_kb.to_string(),
            secs(r.metrics.sample_io_ns + r.metrics.gather_io_ns),
            r.metrics.device.num_requests.to_string(),
        ]);
    }
    t.finish();

    println!("\n=== Figure 9(b): hyperbatch-size sweep (YH) ===\n");
    let mut t = Table::new("fig9b_hyperbatch", &["hyperbatch", "exec_s", "storage_ios"]);
    for hb in [1usize, 4, 16, 64, 128] {
        let mut config = bench_config("yh", 0.01);
        config.train.hyperbatch_size = hb;
        config.io.block_size = 64 << 10;
        config.memory.graph_buffer_bytes = 512 << 10;
        config.memory.feature_buffer_bytes = 512 << 10;
        config.memory.feature_cache_entries = 1024;
        config.train.minibatch_size = 50;
        config.train.target_fraction = 0.4;
        let r = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
        t.row(vec![
            hb.to_string(),
            secs(r.metrics.sample_io_ns + r.metrics.gather_io_ns),
            r.metrics.device.num_requests.to_string(),
        ]);
    }
    t.finish();
    println!(
        "\nShape check vs paper: I/O count falls monotonically with both \
         knobs; execution time is U-shaped in block size (unnecessary bytes \
         dominate past the sweet spot) and saturates in hyperbatch size."
    );
    Ok(())
}
