//! Figure 6 — the headline comparison: AGNES vs Ginex / GNNDrive /
//! MariusGNN / OUTRE across the five datasets under both memory settings
//! (32 GB and 8 GB, scaled), plus the per-model table (MariusGNN and
//! OUTRE are SAGE-only → "N.A.", as in the paper).
//!
//! `cargo bench --bench fig6_main`

use agnes::config::GnnModel;
use agnes::coordinator::ModeledCompute;
use agnes::util::bench::{
    bench_config, run_epoch_by_name, secs, supports, with_setting2, Table, MODELED_COMPUTE_NS,
};

const DATASETS: &[(&str, f64)] =
    &[("ig", 0.5), ("tw", 0.1), ("pa", 0.1), ("fr", 0.05), ("yh", 0.01)];
const SYSTEMS: &[&str] = &["agnes", "ginex", "gnndrive", "mariusgnn", "outre"];

/// Epoch time on the modeled testbed: simulated storage time + modeled
/// compute (host CPU wall is a sandbox artifact — EXPERIMENTS.md
/// §Methodology).
fn epoch_secs(system: &str, config: &agnes::config::AgnesConfig) -> anyhow::Result<(u64, f64)> {
    let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
    let r = run_epoch_by_name(system, config, &mut compute)?;
    let storage = r.metrics.sample_io_ns + r.metrics.gather_io_ns;
    let total = storage + compute.simulated_ns;
    Ok((total, storage as f64 / total.max(1) as f64))
}

fn main() -> anyhow::Result<()> {
    for (setting, is2) in [("Setting 1 (32 GB scaled)", false), ("Setting 2 (8 GB scaled)", true)]
    {
        println!("\n=== Figure 6 {setting}: epoch time (s), SAGE ===\n");
        let mut t = Table::new(
            if is2 { "fig6_setting2" } else { "fig6_setting1" },
            &["dataset", "agnes", "ginex", "gnndrive", "mariusgnn", "outre", "vs_ginex"],
        );
        for &(ds, scale) in DATASETS {
            let mut cells = vec![ds.to_uppercase()];
            let mut agnes_t = 0u64;
            let mut ginex_t = 0u64;
            for &system in SYSTEMS {
                let mut config = bench_config(ds, scale);
                config.train.model = GnnModel::Sage;
                if is2 {
                    config = with_setting2(config);
                }
                let (total, _) = epoch_secs(system, &config)?;
                cells.push(secs(total));
                if system == "agnes" {
                    agnes_t = total;
                } else if system == "ginex" {
                    ginex_t = total;
                }
            }
            // the paper reports speedup over "the best-performing
            // competitor, Ginex"; at 1/1000 scale MariusGNN can degenerate
            // to in-memory training when the scaled dataset fits its
            // buffer (see EXPERIMENTS.md §Fig6)
            cells.push(format!("{:.2}x", ginex_t as f64 / agnes_t.max(1) as f64));
            t.row(cells);
        }
        t.finish();
    }

    println!("\n=== Figure 6 per-model (IG, Setting 1): epoch time (s) ===\n");
    let mut t = Table::new(
        "fig6_models",
        &["model", "agnes", "ginex", "gnndrive", "mariusgnn", "outre"],
    );
    for model in GnnModel::all() {
        let mut cells = vec![model.name().to_string()];
        for &system in SYSTEMS {
            if !supports(system, model) {
                cells.push("N.A.".into());
                continue;
            }
            let mut config = bench_config("ig", 0.5);
            config.train.model = model;
            // GAT aggregates over fanout+1 attendees: model compute cost up
            let mult = if model == GnnModel::Gat { 2 } else { 1 };
            let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS * mult);
            let r = run_epoch_by_name(system, &config, &mut compute)?;
            let storage = r.metrics.sample_io_ns + r.metrics.gather_io_ns;
            cells.push(secs(storage + compute.simulated_ns));
        }
        t.row(cells);
    }
    t.finish();
    println!(
        "\nShape check vs paper: AGNES wins every cell; the gap widens under \
         Setting 2 (paper: up to 3.1x / 4.1x over Ginex)."
    );
    Ok(())
}
