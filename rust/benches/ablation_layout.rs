//! Design-choice ablation (DESIGN.md): the locality-aware data layout
//! (paper §3.2, after RealGraph [9,10]). Same workload, four on-disk node
//! orderings — degree (paper default), BFS, natural (generator), and an
//! adversarial shuffle — measuring blocks touched, storage I/Os and
//! simulated storage time for AGNES's data preparation.
//!
//! `cargo bench --bench ablation_layout`

use agnes::coordinator::NullCompute;
use agnes::graph::layout::Layout;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};

fn main() -> anyhow::Result<()> {
    println!("=== Layout ablation (PA, AGNES data preparation) ===\n");
    let mut t = Table::new(
        "ablation_layout",
        &["layout", "storage_ios", "io_bytes_mb", "storage_time_s", "graph_hits_pct"],
    );
    for (name, layout) in [
        ("degree", Layout::Degree),
        ("bfs", Layout::Bfs),
        ("natural", Layout::Natural),
        ("shuffle", Layout::Shuffle),
    ] {
        let mut c = bench_config("pa", 0.1);
        c.dataset.layout = layout;
        // tight buffers + per-minibatch processing: the hyperbatch sweep
        // reads the whole (scaled) store regardless of order, so the
        // layout's locality shows in the per-minibatch regime, where the
        // frontier of each minibatch maps to few blocks iff co-accessed
        // nodes share blocks
        c.io.block_size = 64 << 10;
        c.memory.graph_buffer_bytes = 512 << 10;
        c.memory.feature_buffer_bytes = 512 << 10;
        c.memory.feature_cache_entries = 1024;
        c.train.minibatch_size = 50;
        let r = run_epoch_by_name("agnes-no", &c, &mut NullCompute)?;
        let m = &r.metrics;
        t.row(vec![
            name.into(),
            m.device.num_requests.to_string(),
            format!("{:.1}", m.device.total_bytes as f64 / 1e6),
            secs(m.sample_io_ns + m.gather_io_ns),
            format!("{:.1}", m.graph_hit_ratio * 100.0),
        ]);
    }
    t.finish();
    println!(
        "\nThe degree layout clusters hubs — the nodes every minibatch hits — \
         into a few always-buffered blocks, cutting reloads vs the shuffled \
         layout (the paper's RealGraph-style design choice)."
    );
    Ok(())
}
