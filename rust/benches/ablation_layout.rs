//! Layout ablation, two levels:
//!
//! 1. **Block layout policies** (`layout.policy = none | degree |
//!    hyperbatch` — the storage layout optimizer of `graph/reorder.rs`):
//!    the dense tiny sweep is the CI-asserted acceptance gate
//!    (`hyperbatch` must reach `mean_blocks_per_run` >= `none` and
//!    `shard_imbalance()` <= `none` on 4 shards, bit-identical loss across
//!    all three policies), and the scattered sweep — shuffled node ids,
//!    tight buffers, multi-hyperbatch epoch — is where the optimizer's
//!    co-access packing visibly lengthens runs vs the `none` layout.
//! 2. **Node-id layouts** (`dataset.layout`, paper §3.2 after RealGraph
//!    [9, 10]) — the original design-choice ablation, kept in full bench
//!    mode.
//!
//! `cargo bench --bench ablation_layout`
//!
//! Set `AGNES_LAYOUT_TINY=1` for the CI smoke configuration (block-policy
//! sweeps only). Either way the bench emits
//! `target/bench_results/BENCH_layout.json` for the perf trajectory and
//! the `bench_gate` regression gate.

use agnes::config::AgnesConfig;
use agnes::coordinator::{EpochResult, NullCompute};
use agnes::graph::layout::Layout;
use agnes::graph::reorder::LayoutPolicy;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};
use agnes::util::json::Json;
use agnes::AgnesRunner;

fn tiny_mode() -> bool {
    std::env::var("AGNES_LAYOUT_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The acceptance workload: one hyperbatch targeting every node with a
/// single sampling level, so both sweeps touch **every** block of both
/// stores. Dense coverage makes the assertion structural: a bijective
/// remap of a fully-covered block range plans into the same run set, so
/// the optimized policies can never do worse than `none` here — while
/// 4 real shards and 64-block stripes exercise the whole
/// translate-plan-charge path.
fn dense_config() -> AgnesConfig {
    let mut c = AgnesConfig::tiny();
    c.dataset.data_dir = "data/bench_layout".into();
    c.dataset.feature_dim = 256; // 1 KiB vectors, 4 per block: 500 blocks
    c.io.block_size = 4 << 10;
    c.io.max_request_bytes = 256 << 10;
    c.device.num_ssds = 4;
    c.memory.graph_buffer_bytes = 8 << 20;
    c.memory.feature_buffer_bytes = 8 << 20;
    c.train.minibatch_size = 64;
    c.train.hyperbatch_size = 64; // > 32 minibatches: one hyperbatch
    c.train.fanouts = vec![5];
    c.train.target_fraction = 1.0;
    c
}

/// The demonstration workload: shuffled node ids scatter each
/// hyperbatch's blocks across the file, tight buffers chunk the sweeps,
/// and `gap_blocks = 0` (pinned by `tiny()`) forbids hole bridging — so
/// under `none` the miss lists fragment into short runs, while the
/// optimizer's co-access packing lines each hyperbatch's blocks up into
/// long physical runs.
fn scattered_config() -> AgnesConfig {
    let mut c = AgnesConfig::tiny();
    c.dataset.data_dir = "data/bench_layout".into();
    c.dataset.layout = Layout::Shuffle;
    c.dataset.feature_dim = 128; // 512 B vectors, 8 per block: 250 blocks
    c.io.block_size = 4 << 10;
    c.io.max_request_bytes = 256 << 10;
    c.device.num_ssds = 4;
    c.memory.graph_buffer_bytes = 256 << 10; // 64 frames << 250 blocks
    c.memory.feature_buffer_bytes = 256 << 10;
    c.memory.feature_cache_entries = 256;
    c.train.minibatch_size = 50;
    c.train.hyperbatch_size = 8;
    c.train.fanouts = vec![5, 5];
    c.train.target_fraction = 0.3;
    c
}

fn run_policy(base: &AgnesConfig, policy: LayoutPolicy) -> anyhow::Result<EpochResult> {
    let mut c = base.clone();
    c.layout.policy = policy;
    let mut r = AgnesRunner::open(c)?;
    r.run_epoch(0, &mut NullCompute)
}

fn policy_json(policy: LayoutPolicy, r: &EpochResult) -> Json {
    let m = &r.metrics;
    Json::obj(vec![
        ("policy", Json::str(policy.name())),
        ("requests", Json::num(m.device.num_requests as f64)),
        ("total_bytes", Json::num(m.device.total_bytes as f64)),
        ("mean_blocks_per_run", Json::num(m.mean_blocks_per_run())),
        ("shard_imbalance", Json::num(m.shard_imbalance())),
        ("prep_storage_s", Json::num((m.sample_io_ns + m.gather_io_ns) as f64 * 1e-9)),
        // hex string so the f32 bit pattern survives JSON exactly
        ("loss_bits", Json::str(format!("0x{:08x}", r.mean_loss.to_bits()))),
    ])
}

/// Run the three policies over one workload, print the table, return the
/// per-policy results + JSON rows.
fn sweep(
    label: &str,
    base: &AgnesConfig,
) -> anyhow::Result<(Vec<(LayoutPolicy, EpochResult)>, Vec<Json>)> {
    let mut t = Table::new(
        &format!("ablation_layout_{label}"),
        &["policy", "requests", "blocks_per_run", "imbalance", "storage_time_s"],
    );
    let mut results = Vec::new();
    let mut rows = Vec::new();
    for policy in LayoutPolicy::all() {
        let r = run_policy(base, policy)?;
        let m = &r.metrics;
        t.row(vec![
            policy.name().into(),
            m.device.num_requests.to_string(),
            format!("{:.1}", m.mean_blocks_per_run()),
            format!("{:.2}", m.shard_imbalance()),
            secs(m.sample_io_ns + m.gather_io_ns),
        ]);
        rows.push(policy_json(policy, &r));
        results.push((policy, r));
    }
    t.finish();
    Ok((results, rows))
}

fn by_policy<'a>(
    results: &'a [(LayoutPolicy, EpochResult)],
    policy: LayoutPolicy,
) -> &'a EpochResult {
    &results.iter().find(|(p, _)| *p == policy).expect("policy ran").1
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();

    println!("=== Block layout policies: dense acceptance sweep (4 shards) ===\n");
    let (dense, dense_json) = sweep("dense", &dense_config())?;
    println!("\n=== Block layout policies: scattered sweep (shuffled ids) ===\n");
    let (scattered, scattered_json) = sweep("scattered", &scattered_config())?;

    // the CI acceptance gate: the optimizer must never lose to `none` on
    // the dense sweep, and no policy may ever change the training data
    for results in [&dense, &scattered] {
        let none = by_policy(results, LayoutPolicy::None);
        for (policy, r) in results.iter() {
            anyhow::ensure!(
                r.mean_loss.to_bits() == none.mean_loss.to_bits()
                    && r.accuracy.to_bits() == none.accuracy.to_bits(),
                "{policy} layout diverged from none: the remap must be a pure translation"
            );
        }
    }
    let none = by_policy(&dense, LayoutPolicy::None);
    let hyper = by_policy(&dense, LayoutPolicy::Hyperbatch);
    anyhow::ensure!(
        hyper.metrics.mean_blocks_per_run() >= none.metrics.mean_blocks_per_run() - 1e-9,
        "hyperbatch layout must coalesce at least as well as none on the dense sweep: {} vs {}",
        hyper.metrics.mean_blocks_per_run(),
        none.metrics.mean_blocks_per_run()
    );
    anyhow::ensure!(
        hyper.metrics.shard_imbalance() <= none.metrics.shard_imbalance() + 1e-9,
        "hyperbatch layout must balance shards at least as well as none on the dense sweep: \
         {} vs {}",
        hyper.metrics.shard_imbalance(),
        none.metrics.shard_imbalance()
    );
    println!(
        "\ndense: hyperbatch {:.1} blocks/run at imbalance {:.2} vs none {:.1} at {:.2}",
        hyper.metrics.mean_blocks_per_run(),
        hyper.metrics.shard_imbalance(),
        none.metrics.mean_blocks_per_run(),
        none.metrics.shard_imbalance(),
    );
    let s_none = by_policy(&scattered, LayoutPolicy::None);
    let s_hyper = by_policy(&scattered, LayoutPolicy::Hyperbatch);
    println!(
        "scattered: hyperbatch {:.1} blocks/run in {} requests vs none {:.1} in {}",
        s_hyper.metrics.mean_blocks_per_run(),
        s_hyper.metrics.device.num_requests,
        s_none.metrics.mean_blocks_per_run(),
        s_none.metrics.device.num_requests,
    );

    // ---- the original node-id layout ablation (full bench mode only) --
    let mut node_json: Vec<Json> = Vec::new();
    if !tiny {
        println!("\n=== Node-id layouts (PA, AGNES data preparation) ===\n");
        let mut t = Table::new(
            "ablation_layout",
            &["layout", "storage_ios", "io_bytes_mb", "storage_time_s", "graph_hits_pct"],
        );
        for (name, layout) in [
            ("degree", Layout::Degree),
            ("bfs", Layout::Bfs),
            ("natural", Layout::Natural),
            ("shuffle", Layout::Shuffle),
        ] {
            let mut c = bench_config("pa", 0.1);
            c.dataset.layout = layout;
            // tight buffers + per-minibatch processing: the hyperbatch
            // sweep reads the whole (scaled) store regardless of order,
            // so the layout's locality shows in the per-minibatch regime
            c.io.block_size = 64 << 10;
            c.memory.graph_buffer_bytes = 512 << 10;
            c.memory.feature_buffer_bytes = 512 << 10;
            c.memory.feature_cache_entries = 1024;
            c.train.minibatch_size = 50;
            let r = run_epoch_by_name("agnes-no", &c, &mut NullCompute)?;
            let m = &r.metrics;
            t.row(vec![
                name.into(),
                m.device.num_requests.to_string(),
                format!("{:.1}", m.device.total_bytes as f64 / 1e6),
                secs(m.sample_io_ns + m.gather_io_ns),
                format!("{:.1}", m.graph_hit_ratio * 100.0),
            ]);
            node_json.push(Json::obj(vec![
                ("layout", Json::str(name)),
                ("requests", Json::num(m.device.num_requests as f64)),
                ("storage_s", Json::num((m.sample_io_ns + m.gather_io_ns) as f64 * 1e-9)),
            ]));
        }
        t.finish();
    }

    // machine-readable perf record for the trajectory / bench_gate
    let report = Json::obj(vec![
        ("bench", Json::str("ablation_layout")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("dense", Json::arr(dense_json)),
        ("scattered", Json::arr(scattered_json)),
        ("node_layouts", Json::arr(node_json)),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_layout.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_layout.json");

    println!(
        "\nThe hyperbatch policy packs each hyperbatch's co-accessed blocks \
         contiguously (longer coalesced runs on the scattered workload) and \
         deals every batch's hottest blocks across stripe boundaries so all \
         shards serve every batch — the Ginex/GIDS placement insight applied \
         to AGNES's block stores."
    );
    Ok(())
}
