//! Figure 8 — ablation: AGNES-No (hyperbatch off, per-minibatch block
//! sweeps) vs AGNES-HB across the five datasets. The paper reports up to
//! 622x; the ratio here depends on how far the working set exceeds the
//! buffers (we also print it under Setting 2 where the effect is larger).
//!
//! `cargo bench --bench fig8_ablation`

use agnes::coordinator::NullCompute;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, with_setting2, Table};

const DATASETS: &[(&str, f64)] =
    &[("ig", 0.5), ("tw", 0.1), ("pa", 0.1), ("fr", 0.05), ("yh", 0.01)];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 8: AGNES-No vs AGNES-HB (data preparation) ===\n");
    let mut t = Table::new(
        "fig8_ablation",
        &["dataset", "setting", "agnes_no_s", "agnes_hb_s", "speedup", "ios_no", "ios_hb"],
    );
    for &(ds, scale) in DATASETS {
        for (setting, is2) in [("S1", false), ("S2", true)] {
            let mut config = bench_config(ds, scale);
            if is2 {
                config = with_setting2(config);
            }
            // the paper's ablation runs where the working set exceeds the
            // buffers (YH >> memory); at 1/1000 dataset scale the buffers
            // must shrink with the data or everything is resident and the
            // ablation measures nothing — keep ~6 blocks of graph buffer
            // and ~6 of feature buffer, scaled smaller for Setting 2
            config.io.block_size = 64 << 10;
            let frames = if is2 { 3 } else { 6 } as u64;
            config.memory.graph_buffer_bytes = frames * config.io.block_size as u64;
            config.memory.feature_buffer_bytes = frames * config.io.block_size as u64;
            config.memory.feature_cache_entries = if is2 { 256 } else { 1024 };
            // more, smaller minibatches so hyperbatching has scope (the
            // scaled epoch would otherwise have a handful of minibatches)
            config.train.minibatch_size = 50;
            config.train.target_fraction = 0.4;
            let r_no = run_epoch_by_name("agnes-no", &config, &mut NullCompute)?;
            let r_hb = run_epoch_by_name("agnes", &config, &mut NullCompute)?;
            // execution time on the modeled testbed = simulated storage
            // time (host CPU wall is an artifact of this sandbox; see
            // EXPERIMENTS.md §Methodology)
            let t_no = r_no.metrics.sample_io_ns + r_no.metrics.gather_io_ns;
            let t_hb = r_hb.metrics.sample_io_ns + r_hb.metrics.gather_io_ns;
            t.row(vec![
                ds.to_uppercase(),
                setting.into(),
                secs(t_no),
                secs(t_hb),
                format!("{:.1}x", t_no as f64 / t_hb.max(1) as f64),
                r_no.metrics.device.num_requests.to_string(),
                r_hb.metrics.device.num_requests.to_string(),
            ]);
        }
    }
    t.finish();
    println!(
        "\nShape check vs paper: hyperbatch-based processing removes the \
         per-minibatch block reloads; the win grows when memory is tighter."
    );
    Ok(())
}
