//! Multi-tenant fair-share I/O scheduling over the shared SSD array —
//! the fairness/isolation experiment for the tenant-aware scheduler
//! (backlog-proportional lane budgets, deficit-round-robin shares, AIMD
//! congestion backoff; see README §Multi-tenancy).
//!
//! Three legs, all on a 4-shard array:
//!
//! 1. **Fairness sweep** — 1/2/4 equal-share tenants submitting the same
//!    bandwidth-bound trace round-robin. Asserts each of 2 concurrent
//!    tenants keeps ≥ 45% of the solo modeled throughput, and each of 4
//!    keeps its deficit-round-robin guarantee (1/4 of device time).
//! 2. **Hot tenant** — one tenant floods 10x the volume of a light
//!    tenant. Asserts the light tenant never starves (achieved share ≥
//!    its fair-share guarantee) and the hot tenant's AIMD backoff
//!    actually engages.
//! 3. **Solo epoch identity** — a full training epoch with multi-tenancy
//!    registered but no competitor submitting must be **bit-identical**
//!    (loss bits + device counters) to the unregistered path.
//!
//! `cargo bench --bench fig_multitenant`
//!
//! Set `AGNES_MT_TINY=1` for the CI smoke configuration. Either way the
//! bench emits `target/bench_results/BENCH_multitenant.json`.

use agnes::coordinator::NullCompute;
use agnes::storage::device::{
    IoBatch, SharedArray, SsdArray, SsdSpec, TenantId, TenantStats, TENANT_DEFAULT,
};
use agnes::util::bench::{bench_config, run_epoch_by_name, Table};
use agnes::util::json::Json;

const SHARDS: u32 = 4;

fn tiny_mode() -> bool {
    std::env::var("AGNES_MT_TINY").map(|v| v == "1").unwrap_or(false)
}

fn fresh_array() -> SharedArray {
    SsdArray::sharded(SsdSpec::default().with_ssds(SHARDS), 0)
}

fn stat_for(stats: &[(TenantId, TenantStats)], id: TenantId) -> TenantStats {
    stats.iter().find(|(t, _)| *t == id).map(|(_, s)| *s).unwrap_or_default()
}

/// Modeled throughput a tenant experienced: bytes over the wall time its
/// submissions occupied (service + interference stall).
fn modeled_gbps(s: &TenantStats) -> f64 {
    if s.busy_ns + s.stall_ns == 0 {
        return 0.0;
    }
    s.bytes as f64 / (s.busy_ns + s.stall_ns) as f64
}

/// One fairness leg: `n` equal-share tenants round-robin the same
/// bandwidth-bound batch (8 MiB per shard per submit — large enough that
/// the bandwidth term dominates, small enough that equal interleaving
/// stays under the congestion threshold).
fn fairness_leg(n: usize, rounds: usize) -> Vec<(TenantId, TenantStats)> {
    let ssd = fresh_array();
    for t in 0..n {
        ssd.register_tenant(t as TenantId, 1.0 / n as f64, 0);
    }
    let batch: Vec<Vec<u64>> = (0..SHARDS).map(|_| vec![1u64 << 20; 8]).collect();
    for _ in 0..rounds {
        for t in 0..n {
            ssd.submit(&IoBatch::shard_sizes(&batch).for_tenant(t as TenantId), 32);
        }
    }
    ssd.tenant_stats()
}

/// Hot-tenant leg: equal shares, 10x volume imbalance. Returns
/// (light, hot, max hot backoff observed).
fn hot_tenant_leg(rounds: usize) -> (TenantStats, TenantStats, u32) {
    const LIGHT: TenantId = 0;
    const HOT: TenantId = 1;
    let ssd = fresh_array();
    ssd.register_tenant(LIGHT, 0.5, 0);
    ssd.register_tenant(HOT, 0.5, 0);
    let hot_batch: Vec<Vec<u64>> = (0..SHARDS).map(|_| vec![1u64 << 21; 10]).collect();
    let light_batch: Vec<Vec<u64>> = (0..SHARDS).map(|_| vec![1u64 << 20; 2]).collect();
    let mut max_backoff = 0;
    for _ in 0..rounds {
        ssd.submit(&IoBatch::shard_sizes(&hot_batch).for_tenant(HOT), 32);
        max_backoff = max_backoff.max(ssd.tenant_backoff(HOT));
        ssd.submit(&IoBatch::shard_sizes(&light_batch).for_tenant(LIGHT), 16);
    }
    let stats = ssd.tenant_stats();
    (stat_for(&stats, LIGHT), stat_for(&stats, HOT), max_backoff)
}

fn tenant_json(id: TenantId, s: &TenantStats) -> Json {
    Json::obj(vec![
        ("tenant", Json::num(id as f64)),
        ("requests", Json::num(s.requests as f64)),
        ("total_bytes", Json::num(s.bytes as f64)),
        ("busy_ns", Json::num(s.busy_ns as f64)),
        ("stall_ns", Json::num(s.stall_ns as f64)),
        ("achieved_share", Json::num(s.achieved_share())),
        ("modeled_gbps", Json::num(modeled_gbps(s))),
    ])
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();
    let rounds = if tiny { 16 } else { 128 };

    // ---- leg 1: equal-share fairness sweep -----------------------------
    println!("=== Multi-tenant fairness sweep (4-shard array) ===\n");
    let mut t = Table::new(
        "multitenant_fairness",
        &["tenants", "tenant", "achieved_share", "modeled_gbps", "stall_ms"],
    );
    let mut sweep_json: Vec<Json> = Vec::new();
    let mut solo_gbps = 0.0;
    for n in [1usize, 2, 4] {
        let stats = fairness_leg(n, rounds);
        for (id, s) in &stats {
            t.row(vec![
                n.to_string(),
                id.to_string(),
                format!("{:.3}", s.achieved_share()),
                format!("{:.2}", modeled_gbps(s)),
                format!("{:.2}", s.stall_ns as f64 / 1e6),
            ]);
            sweep_json.push(Json::obj(vec![
                ("tenants", Json::num(n as f64)),
                ("detail", tenant_json(*id, s)),
            ]));
        }
        let solo = stat_for(&stats, 0);
        match n {
            1 => {
                solo_gbps = modeled_gbps(&solo);
                anyhow::ensure!(
                    solo.stall_ns == 0 && solo.achieved_share() == 1.0,
                    "a solo tenant must see zero interference"
                );
            }
            2 => {
                for (id, s) in &stats {
                    anyhow::ensure!(
                        modeled_gbps(s) >= 0.45 * solo_gbps,
                        "tenant {id} of 2 got {:.2} GB/s, < 45% of solo {:.2} GB/s",
                        modeled_gbps(s),
                        solo_gbps
                    );
                }
            }
            _ => {
                for (id, s) in &stats {
                    anyhow::ensure!(
                        s.achieved_share() >= 0.25 * 0.99,
                        "tenant {id} of 4 got share {:.3}, below the DRR guarantee",
                        s.achieved_share()
                    );
                }
            }
        }
    }
    t.finish();

    // ---- leg 2: hot tenant vs light tenant -----------------------------
    let (light, hot, hot_backoff) = hot_tenant_leg(if tiny { 12 } else { 32 });
    println!(
        "\nhot-tenant leg: light share {:.3} ({} reqs), hot share {:.3} ({} reqs), max hot backoff {}",
        light.achieved_share(),
        light.requests,
        hot.achieved_share(),
        hot.requests,
        hot_backoff
    );
    anyhow::ensure!(light.busy_ns > 0, "light tenant did no work under the hot tenant");
    anyhow::ensure!(
        light.achieved_share() >= 0.5 * 0.999,
        "light tenant starved: achieved {:.4} < fair-share guarantee 0.5",
        light.achieved_share()
    );
    anyhow::ensure!(
        hot_backoff >= 1,
        "hot tenant never hit AIMD backoff despite a 10x backlog lead"
    );

    // ---- leg 3: solo epoch identity (registered vs unregistered) -------
    // Unlike the fairness legs (pinned to 4 shards), this one honors the
    // AGNES_NUM_SSDS override bench_config applied, so the CI matrix
    // proves identity on both the 1-shard and 4-shard legs.
    let c = if tiny { bench_config("tiny", 1.0) } else { bench_config("ig", 0.5) };
    let base = run_epoch_by_name("agnes", &c, &mut NullCompute)?;
    let mut c2 = c.clone();
    c2.tenant.share = 0.6; // registers train@0.6 / serve@0.4; serve stays idle
    let reg = run_epoch_by_name("agnes", &c2, &mut NullCompute)?;
    println!(
        "\nepoch identity: loss {:#010x} vs {:#010x}, {} vs {} requests",
        base.mean_loss.to_bits(),
        reg.mean_loss.to_bits(),
        base.metrics.device.num_requests,
        reg.metrics.device.num_requests
    );
    anyhow::ensure!(
        base.mean_loss.to_bits() == reg.mean_loss.to_bits(),
        "registering an idle tenant changed the training loss bits"
    );
    anyhow::ensure!(
        base.metrics.device.num_requests == reg.metrics.device.num_requests
            && base.metrics.device.total_bytes == reg.metrics.device.total_bytes
            && base.metrics.device.busy_ns == reg.metrics.device.busy_ns
            && base.metrics.shards.busy_ns == reg.metrics.shards.busy_ns,
        "registering an idle tenant changed the device counters"
    );
    let train = TENANT_DEFAULT as usize;
    anyhow::ensure!(
        reg.metrics.tenants.get(train).map_or(0, |t| t.requests) > 0,
        "registered epoch attributed no requests to the training tenant"
    );
    anyhow::ensure!(
        reg.metrics.tenants.iter().map(|t| t.stall_ns).sum::<u64>() == 0,
        "solo training epoch accrued interference stall"
    );

    // machine-readable perf record for the trajectory
    let report = Json::obj(vec![
        ("bench", Json::str("fig_multitenant")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("fairness_sweep", Json::arr(sweep_json)),
        (
            "hot_tenant",
            Json::obj(vec![
                ("light", tenant_json(0, &light)),
                ("hot", tenant_json(1, &hot)),
                ("max_hot_backoff", Json::num(hot_backoff as f64)),
            ]),
        ),
        (
            "epoch_identity",
            Json::obj(vec![
                ("num_ssds", Json::num(c.device.num_ssds as f64)),
                ("requests", Json::num(reg.metrics.device.num_requests as f64)),
                ("total_bytes", Json::num(reg.metrics.device.total_bytes as f64)),
                // hex string so the f32 bit pattern is gated exactly
                ("loss_bits", Json::str(format!("0x{:08x}", reg.mean_loss.to_bits()))),
            ]),
        ),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_multitenant.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_multitenant.json");

    println!(
        "\nShape check: with equal shares each tenant's modeled throughput \
         tracks 1/N of the array (deficit-round-robin), a 10x hot tenant \
         cannot push the light tenant below its guarantee (AIMD backoff \
         absorbs the backlog), and a registered-but-solo tenant pays \
         nothing — the scheduler is work-conserving."
    );
    Ok(())
}
