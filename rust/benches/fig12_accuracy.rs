//! Figure 12 — accuracy per training time: AGNES vs Ginex training the
//! same model (real AOT-compiled XLA compute) on IG; both reach the same
//! accuracy per epoch, AGNES just gets there sooner (its prep is cheaper).
//!
//! Requires `make artifacts`. `cargo bench --bench fig12_accuracy`

use agnes::baselines::{GinexRunner, TrainingSystem};
use agnes::config::AgnesConfig;
use agnes::runtime::{ArtifactPaths, XlaCompute};
use agnes::util::bench::Table;
use agnes::AgnesRunner;

const EPOCHS: usize = 6;

fn config() -> AgnesConfig {
    let mut c = AgnesConfig::default();
    c.dataset.name = "ig".into();
    c.dataset.scale = 1.0;
    c.dataset.feature_dim = 32; // artifact shapes
    c.dataset.data_dir = "data/bench".into();
    c.io.block_size = 64 << 10;
    c.memory.graph_buffer_bytes = 1 << 20;
    c.memory.feature_buffer_bytes = 1 << 20;
    c.memory.feature_cache_entries = 2048;
    c.train.minibatch_size = 64;
    c.train.hyperbatch_size = 32;
    c.train.fanouts = vec![5, 5];
    c.train.target_fraction = 0.10;
    c
}

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        ArtifactPaths::in_dir("artifacts", "sage").exist(),
        "run `make artifacts` first"
    );
    println!("=== Figure 12: accuracy vs training time (IG, SAGE, real XLA) ===\n");
    let mut t = Table::new(
        "fig12_accuracy",
        &["system", "epoch", "cum_time_s", "loss", "accuracy"],
    );
    for system in ["agnes", "ginex"] {
        let mut compute = XlaCompute::load("artifacts", "sage")?;
        let mut agnes;
        let mut ginex;
        let sys: &mut dyn TrainingSystem = if system == "agnes" {
            agnes = AgnesRunner::open(config())?;
            &mut agnes
        } else {
            ginex = GinexRunner::open(config())?;
            &mut ginex
        };
        let mut cum_ns = 0u64;
        for epoch in 0..EPOCHS {
            // fixed target set (epoch seed 0): clean optimization trace
            let r = sys.run_training_epoch(0, &mut compute)?;
            cum_ns += r.metrics.total_ns();
            t.row(vec![
                system.into(),
                epoch.to_string(),
                format!("{:.3}", cum_ns as f64 * 1e-9),
                format!("{:.4}", r.mean_loss),
                format!("{:.3}", r.accuracy),
            ]);
        }
    }
    t.finish();
    println!(
        "\nShape check vs paper: identical accuracy trajectory per epoch (same \
         samples, same step), smaller cumulative time for AGNES — higher \
         accuracy per unit time."
    );
    Ok(())
}
