//! Figure 10 — sensitivity sweeps.
//!
//! The CI-asserted core is the **cache-policy sensitivity sweep**:
//! reactive vs belady (trace-optimal) eviction across feature-cache
//! capacities on a multi-hyperbatch workload. Each policy runs the same
//! epoch twice — a warm pass that (under belady) records the live access
//! trace and installs the Belady schedule, then a measured pass over the
//! identical epoch so the schedule replays the exact stream it was built
//! from. Acceptance: the access stream and training values are
//! bit-identical across policies at every capacity, belady's hit count is
//! never below reactive's, and at the tightest capacity it is strictly
//! higher (Belady/MIN is provably optimal on an exact replay).
//!
//! The legacy Figure 10(a)-(e) sweeps (buffer size, CPU threads, feature
//! dimension, fanout, SSD array size — AGNES vs Ginex) remain in full
//! bench mode.
//!
//! `cargo bench --bench fig10_sensitivity`
//!
//! Set `AGNES_FIG10_TINY=1` for the CI smoke configuration (cache-policy
//! sweep only). Either way the bench emits
//! `target/bench_results/BENCH_fig10.json` for the perf trajectory and
//! the `bench_gate` regression gate.

use agnes::config::AgnesConfig;
use agnes::coordinator::{EpochResult, NullCompute};
use agnes::memory::CachePolicy;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};
use agnes::util::json::Json;
use agnes::AgnesRunner;

fn tiny_mode() -> bool {
    std::env::var("AGNES_FIG10_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The cache-policy workload: every node is a target across a
/// multi-hyperbatch epoch with two sampling levels, so feature vectors
/// repeat heavily within and across hyperbatches — the regime where the
/// eviction decision matters. The count-based admission threshold stays
/// at 2 (the paper's reactive default), which is exactly what the
/// trace-optimal policy gets to beat.
fn cache_sweep_config() -> AgnesConfig {
    let mut c = AgnesConfig::tiny();
    c.dataset.data_dir = "data/bench_fig10".into();
    c.io.block_size = 4 << 10;
    c.memory.graph_buffer_bytes = 1 << 20;
    c.memory.feature_buffer_bytes = 1 << 20;
    c.memory.feature_cache_threshold = 2;
    c.train.minibatch_size = 50;
    c.train.hyperbatch_size = 4;
    c.train.fanouts = vec![5, 5];
    c.train.target_fraction = 1.0;
    c
}

/// Warm-then-measure one (capacity, policy) cell: the warm pass lets
/// belady record its trace and install the schedule at the epoch
/// boundary; `reset_counters` zeroes the stats and rewinds the schedule
/// without dropping it; the measured pass replays the identical epoch.
fn measure(
    base: &AgnesConfig,
    capacity: usize,
    policy: CachePolicy,
) -> anyhow::Result<EpochResult> {
    let mut c = base.clone();
    c.memory.feature_cache_entries = capacity;
    c.cache.policy = policy;
    let mut r = AgnesRunner::open(c)?;
    r.run_epoch(0, &mut NullCompute)?;
    r.reset_counters();
    r.run_epoch(0, &mut NullCompute)
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();
    let capacities: &[usize] = &[64, 128, 256, 512];
    let base = cache_sweep_config();

    println!("=== Figure 10(f): cache eviction policy vs feature-cache capacity ===\n");
    let mut t = Table::new(
        "fig10f_cache_policy",
        &["capacity", "reactive_hit_pct", "belady_hit_pct", "delta_pp", "belady_evictions"],
    );
    let mut rows = Vec::new();
    for (i, &capacity) in capacities.iter().enumerate() {
        let ra = measure(&base, capacity, CachePolicy::Reactive)?;
        let rb = measure(&base, capacity, CachePolicy::Belady)?;
        let (ma, mb) = (&ra.metrics, &rb.metrics);

        // the policy may move residency, never the access stream or the
        // training values
        anyhow::ensure!(
            ma.feature_cache_hits + ma.feature_cache_misses
                == mb.feature_cache_hits + mb.feature_cache_misses,
            "capacity {capacity}: access streams diverged ({} vs {} accesses)",
            ma.feature_cache_hits + ma.feature_cache_misses,
            mb.feature_cache_hits + mb.feature_cache_misses,
        );
        anyhow::ensure!(
            ra.mean_loss.to_bits() == rb.mean_loss.to_bits()
                && ra.accuracy.to_bits() == rb.accuracy.to_bits()
                && ma.sampled_nodes == mb.sampled_nodes
                && ma.gathered_features == mb.gathered_features,
            "capacity {capacity}: belady changed the training outcome"
        );
        // Belady/MIN replaying the exact trace it was built from can
        // never lose to a reactive policy...
        anyhow::ensure!(
            mb.feature_cache_hits >= ma.feature_cache_hits,
            "capacity {capacity}: belady hit count {} below reactive {}",
            mb.feature_cache_hits,
            ma.feature_cache_hits,
        );
        // ...and under real eviction pressure it must strictly win
        if i == 0 {
            anyhow::ensure!(
                mb.feature_cache_hits > ma.feature_cache_hits,
                "tightest capacity {capacity}: belady must strictly beat reactive \
                 ({} vs {} hits)",
                mb.feature_cache_hits,
                ma.feature_cache_hits,
            );
        }

        let (hr_a, hr_b) = (ma.feature_cache_hit_rate(), mb.feature_cache_hit_rate());
        t.row(vec![
            capacity.to_string(),
            format!("{:.1}", hr_a * 100.0),
            format!("{:.1}", hr_b * 100.0),
            format!("{:+.1}", (hr_b - hr_a) * 100.0),
            mb.feature_cache_evictions.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("capacity", Json::num(capacity as f64)),
            ("reactive_hit_rate", Json::num(hr_a)),
            ("belady_hit_rate", Json::num(hr_b)),
            ("reactive_hits", Json::num(ma.feature_cache_hits as f64)),
            ("belady_hits", Json::num(mb.feature_cache_hits as f64)),
            ("gather_storage_s", Json::num(mb.gather_io_ns as f64 * 1e-9)),
            // hex string so the f32 bit pattern survives JSON exactly
            ("loss_bits", Json::str(format!("0x{:08x}", rb.mean_loss.to_bits()))),
        ]));
    }
    t.finish();

    // machine-readable perf record for the trajectory / bench_gate
    let report = Json::obj(vec![
        ("bench", Json::str("fig10_sensitivity")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("cache_capacities", Json::arr(rows)),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_fig10.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_fig10.json");

    if tiny {
        return Ok(());
    }

    // ---- the legacy Figure 10 sensitivity sweeps (full bench mode) ----
    let prep = |system: &str, config: &AgnesConfig| -> anyhow::Result<u64> {
        let m = run_epoch_by_name(system, config, &mut NullCompute)?.metrics;
        Ok(m.sample_io_ns + m.gather_io_ns)
    };
    // wall + simulated time — for the thread sweep, where the CPU-side
    // parallelism of the preparation pipeline is exactly what is measured
    let prep_wall = |system: &str, config: &AgnesConfig| -> anyhow::Result<u64> {
        Ok(run_epoch_by_name(system, config, &mut NullCompute)?.metrics.prep_ns())
    };
    let legacy = || bench_config("pa", 0.1);

    println!("\n=== Figure 10(a): buffer size (MB, scaled from 1-16 GB) ===\n");
    let mut t = Table::new("fig10a_buffer", &["buffer_mb", "agnes_s", "ginex_s"]);
    for mb in [1u64, 2, 4, 8, 16] {
        let mut c = legacy();
        c.memory.graph_buffer_bytes = mb << 20;
        c.memory.feature_buffer_bytes = mb << 20;
        c.memory.feature_cache_entries = (mb as usize) * 512;
        t.row(vec![mb.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();

    println!("\n=== Figure 10(b): CPU threads ===\n");
    let mut t = Table::new("fig10b_threads", &["threads", "agnes_s", "ginex_s"]);
    for threads in [1usize, 2, 4, 8, 16] {
        let mut c = legacy();
        c.io.num_threads = threads;
        t.row(vec![
            threads.to_string(),
            secs(prep_wall("agnes", &c)?),
            secs(prep_wall("ginex", &c)?),
        ]);
    }
    t.finish();

    println!("\n=== Figure 10(c): feature dimension ===\n");
    let mut t = Table::new("fig10c_feature_dim", &["dim", "agnes_s", "ginex_s", "speedup"]);
    for dim in [64usize, 128, 256, 512] {
        let mut c = legacy();
        c.dataset.feature_dim = dim;
        let (a, g) = (prep("agnes", &c)?, prep("ginex", &c)?);
        t.row(vec![
            dim.to_string(),
            secs(a),
            secs(g),
            format!("{:.2}x", g as f64 / a.max(1) as f64),
        ]);
    }
    t.finish();

    println!("\n=== Figure 10(d): sampling size per layer ===\n");
    let mut t = Table::new("fig10d_fanout", &["fanout", "agnes_s", "ginex_s"]);
    for fan in [5usize, 10, 15] {
        let mut c = legacy();
        c.train.fanouts = vec![fan; 3];
        t.row(vec![fan.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();

    println!("\n=== Figure 10(e): SSD array size (RAID0) ===\n");
    let mut t = Table::new("fig10e_ssds", &["ssds", "agnes_s", "ginex_s"]);
    for ssds in [1u32, 2, 4] {
        let mut c = legacy();
        c.device.num_ssds = ssds;
        t.row(vec![ssds.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();
    println!(
        "\nShape check vs paper: belady's hit-rate edge is largest at tight \
         cache capacities; AGNES is flat in buffer size, scales with \
         threads and SSDs, wins more at small feature dims; Ginex is \
         insensitive to extra SSDs (latency-bound)."
    );
    Ok(())
}
