//! Figure 10 — sensitivity of AGNES vs Ginex to (a) buffer size,
//! (b) CPU threads, (c) feature dimension, (d) sampling fanout,
//! (e) SSD array size.
//!
//! `cargo bench --bench fig10_sensitivity`

use agnes::coordinator::NullCompute;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};

/// Simulated storage time (the modeled testbed's data-prep cost).
fn prep(system: &str, config: &agnes::config::AgnesConfig) -> anyhow::Result<u64> {
    let m = run_epoch_by_name(system, config, &mut NullCompute)?.metrics;
    Ok(m.sample_io_ns + m.gather_io_ns)
}

/// Wall + simulated time — used for the thread sweep, where the CPU-side
/// parallelism of the preparation pipeline is exactly what is measured.
fn prep_wall(system: &str, config: &agnes::config::AgnesConfig) -> anyhow::Result<u64> {
    Ok(run_epoch_by_name(system, config, &mut NullCompute)?.metrics.prep_ns())
}

fn main() -> anyhow::Result<()> {
    let base = || bench_config("pa", 0.1);

    println!("=== Figure 10(a): buffer size (MB, scaled from 1-16 GB) ===\n");
    let mut t = Table::new("fig10a_buffer", &["buffer_mb", "agnes_s", "ginex_s"]);
    for mb in [1u64, 2, 4, 8, 16] {
        let mut c = base();
        c.memory.graph_buffer_bytes = mb << 20;
        c.memory.feature_buffer_bytes = mb << 20;
        c.memory.feature_cache_entries = (mb as usize) * 512;
        t.row(vec![mb.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();

    println!("\n=== Figure 10(b): CPU threads ===\n");
    let mut t = Table::new("fig10b_threads", &["threads", "agnes_s", "ginex_s"]);
    for threads in [1usize, 2, 4, 8, 16] {
        let mut c = base();
        c.io.num_threads = threads;
        t.row(vec![
            threads.to_string(),
            secs(prep_wall("agnes", &c)?),
            secs(prep_wall("ginex", &c)?),
        ]);
    }
    t.finish();

    println!("\n=== Figure 10(c): feature dimension ===\n");
    let mut t = Table::new("fig10c_feature_dim", &["dim", "agnes_s", "ginex_s", "speedup"]);
    for dim in [64usize, 128, 256, 512] {
        let mut c = base();
        c.dataset.feature_dim = dim;
        let (a, g) = (prep("agnes", &c)?, prep("ginex", &c)?);
        t.row(vec![
            dim.to_string(),
            secs(a),
            secs(g),
            format!("{:.2}x", g as f64 / a.max(1) as f64),
        ]);
    }
    t.finish();

    println!("\n=== Figure 10(d): sampling size per layer ===\n");
    let mut t = Table::new("fig10d_fanout", &["fanout", "agnes_s", "ginex_s"]);
    for fan in [5usize, 10, 15] {
        let mut c = base();
        c.train.fanouts = vec![fan; 3];
        t.row(vec![fan.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();

    println!("\n=== Figure 10(e): SSD array size (RAID0) ===\n");
    let mut t = Table::new("fig10e_ssds", &["ssds", "agnes_s", "ginex_s"]);
    for ssds in [1u32, 2, 4] {
        let mut c = base();
        c.device.num_ssds = ssds;
        t.row(vec![ssds.to_string(), secs(prep("agnes", &c)?), secs(prep("ginex", &c)?)]);
    }
    t.finish();
    println!(
        "\nShape check vs paper: AGNES is flat in buffer size, scales with \
         threads and SSDs, wins more at small feature dims; Ginex is \
         insensitive to extra SSDs (latency-bound)."
    );
    Ok(())
}
