//! Figure 4 — why "just use bigger I/Os" fails: Ginex on PA with the
//! storage I/O unit swept 4 KB → 4 MB. Total I/O volume explodes while
//! the cache hit ratio collapses (each cached entry costs a whole unit).
//!
//! `cargo bench --bench fig4_unit_size`

use agnes::baselines::{GinexRunner, TrainingSystem};
use agnes::coordinator::NullCompute;
use agnes::metrics::fmt_bytes;
use agnes::util::bench::{bench_config, secs, Table};

const UNITS: &[u64] = &[4 << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 4: Ginex with varying storage I/O unit sizes (PA) ===\n");
    let config = bench_config("pa", 0.1);
    let mut t = Table::new(
        "fig4_unit_size",
        &["io_unit", "total_io_bytes", "cache_hit_pct", "storage_s", "requests"],
    );
    for &unit in UNITS {
        let mut g = GinexRunner::open_with_io_unit(config.clone(), unit)?;
        let r = g.run_training_epoch(0, &mut NullCompute)?;
        let m = &r.metrics;
        t.row(vec![
            fmt_bytes(unit),
            fmt_bytes(m.device.total_bytes),
            format!("{:.2}", m.feature_hit_ratio * 100.0),
            secs(m.sample_io_ns + m.gather_io_ns),
            m.device.num_requests.to_string(),
        ]);
    }
    t.finish();
    println!(
        "\nShape check vs paper: I/O volume grows monotonically with the unit \
         size while the hit ratio collapses — bigger units are not a fix."
    );
    Ok(())
}
