//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! wall-clock throughput of the L3 primitives — block decode, bucket
//! build, hyperbatch sampling sweep, hyperbatch gather sweep — measured
//! with the device model silenced (pure CPU cost).
//!
//! `cargo bench --bench micro_hotpath`
//!
//! Set `AGNES_MICRO_TINY=1` for the CI smoke configuration (tiny dataset,
//! 4 KiB blocks — exercises the same hot loops in seconds).

use agnes::config::AgnesConfig;
use agnes::coordinator::NullCompute;
use agnes::memory::{SharedBufferPool, SharedFeatureCache};
use agnes::op::bucket::Bucket;
use agnes::op::{gather_hyperbatch, sample_hyperbatch};
use agnes::storage::block::GraphBlock;
use agnes::storage::IoEngine;
use agnes::util::bench::{bench_config, Table};
use agnes::AgnesRunner;
use std::time::Instant;

fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

fn tiny_mode() -> bool {
    std::env::var("AGNES_MICRO_TINY").map(|v| v == "1").unwrap_or(false)
}

fn main() -> anyhow::Result<()> {
    // free device: isolate CPU cost of the hot loops
    let mut config: AgnesConfig = if tiny_mode() {
        let mut c = bench_config("tiny", 1.0);
        c.io.block_size = 4 << 10;
        c
    } else {
        bench_config("pa", 0.1)
    };
    config.device.bandwidth = 1e15;
    config.device.request_overhead = 0.0;
    let mut runner = AgnesRunner::open(config.clone())?;
    let hbs = runner.epoch_hyperbatches(0);
    let hb = &hbs[0];
    let targets_total: usize = hb.iter().map(Vec::len).sum();

    let mut t = Table::new("micro_hotpath", &["primitive", "items", "secs", "throughput"]);

    // 1. block decode
    let raw = runner.graph_store.read_block_raw(agnes::storage::BlockId(0), 1)?;
    let (_, dt) = time(|| {
        for _ in 0..2000 {
            std::hint::black_box(GraphBlock::decode(&raw));
        }
    });
    t.row(vec![
        "block_decode".into(),
        "2000 blocks".into(),
        format!("{dt:.4}"),
        format!("{:.0} MB/s", 2000.0 * raw.len() as f64 / dt / 1e6),
    ]);

    // 2. bucket build over the hyperbatch frontier
    let (bucket, dt) = time(|| Bucket::for_graph(hb, runner.graph_store.index()));
    t.row(vec![
        "bucket_build".into(),
        format!("{} entries", bucket.num_entries()),
        format!("{dt:.4}"),
        format!("{:.2} M entries/s", bucket.num_entries() as f64 / dt / 1e6),
    ]);

    // 3. hyperbatch sampling sweep
    let engine = IoEngine::new(config.io.num_threads, config.io.async_depth);
    let pool = SharedBufferPool::new(config.graph_buffer_blocks());
    let (out, dt) = time(|| {
        sample_hyperbatch(&runner.graph_store, &pool, &engine, hb, &[10, 10, 10], 1).unwrap()
    });
    let sampled = out.total_sampled();
    t.row(vec![
        "sample_hyperbatch".into(),
        format!("{sampled} nodes"),
        format!("{dt:.4}"),
        format!("{:.2} M nodes/s", sampled as f64 / dt / 1e6),
    ]);

    // 4. hyperbatch gather sweep
    let node_sets: Vec<Vec<u32>> = (0..hb.len()).map(|mb| out.flat_nodes(mb)).collect();
    let gathered: usize = node_sets.iter().map(Vec::len).sum();
    let fpool = SharedBufferPool::new(config.feature_buffer_blocks());
    let cache = SharedFeatureCache::new(config.memory.feature_cache_entries, 2);
    let (_, dt) = time(|| {
        gather_hyperbatch(&runner.feature_store, &fpool, &cache, &engine, &node_sets)
            .unwrap()
    });
    t.row(vec![
        "gather_hyperbatch".into(),
        format!("{gathered} vectors"),
        format!("{dt:.4}"),
        format!(
            "{:.2} M vec/s ({:.0} MB/s)",
            gathered as f64 / dt / 1e6,
            gathered as f64 * config.dataset.feature_dim as f64 * 4.0 / dt / 1e6
        ),
    ]);

    // 5. full prep epoch wall (CPU only)
    let (r, dt) = time(|| runner.run_epoch(0, &mut NullCompute).unwrap());
    t.row(vec![
        "prep_epoch_wall".into(),
        format!("{} targets", targets_total),
        format!("{dt:.4}"),
        format!("{:.2} K targets/s", targets_total as f64 / dt / 1e3),
    ]);
    let _ = r;
    t.finish();
    Ok(())
}
