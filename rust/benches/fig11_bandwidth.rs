//! Figure 11 — I/O bandwidth scaling as the SSD array grows (paper:
//! AGNES reaches 17.3 GB/s on 4 RAID0 drives; Ginex cannot saturate even
//! one).
//!
//! Since the sharded storage backend, `num_ssds = N` means N **real**
//! shards for AGNES: per-device queues and busy clocks with stripe-mapped
//! block ownership, so this bench measures genuine multi-queue behaviour
//! (balance included) instead of an analytic bandwidth multiplier. The
//! baselines intentionally keep the single-queue aggregate model — their
//! failure to scale is the experiment.
//!
//! `cargo bench --bench fig11_bandwidth`
//!
//! Set `AGNES_FIG11_TINY=1` for the CI smoke configuration (one dense
//! tiny sweep, seconds instead of minutes). Either way the bench emits
//! `target/bench_results/BENCH_fig11.json` with, per shard count, the
//! prepare storage time, achieved bandwidth, utilization, and the
//! per-shard busy clocks + imbalance ratio — and **asserts** that the
//! dense sweep's 2-shard storage time does not exceed the 1-shard time
//! while the loss stays bit-identical.

use agnes::config::AgnesConfig;
use agnes::coordinator::{EpochResult, NullCompute};
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table};
use agnes::util::json::Json;

const DATASETS: &[(&str, f64)] = &[("ig", 0.5), ("tw", 0.1), ("pa", 0.1), ("fr", 0.05), ("yh", 0.01)];
const SSDS: [u32; 3] = [1, 2, 4];

fn tiny_mode() -> bool {
    std::env::var("AGNES_FIG11_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The dense-sweep workload: one hyperbatch targeting every node, big
/// buffers, 256 KiB requests — enough runs per batch that all four
/// shards get work, and bandwidth-bound enough that the scaling is the
/// bandwidth term's.
fn dense_config(tiny: bool) -> AgnesConfig {
    let mut c = if tiny { bench_config("tiny", 1.0) } else { bench_config("ig", 0.5) };
    c.dataset.feature_dim = 256;
    c.io.block_size = 4 << 10;
    c.io.max_request_bytes = 256 << 10;
    c.memory.graph_buffer_bytes = 16 << 20;
    c.memory.feature_buffer_bytes = 16 << 20;
    c.memory.feature_cache_entries = 1024;
    c.train.minibatch_size = 64;
    c.train.hyperbatch_size = 64;
    c.train.target_fraction = 1.0;
    c
}

fn shard_json(ssds: u32, r: &EpochResult) -> Json {
    let m = &r.metrics;
    Json::obj(vec![
        ("num_ssds", Json::num(ssds as f64)),
        ("prep_storage_s", Json::num((m.sample_io_ns + m.gather_io_ns) as f64 * 1e-9)),
        ("prep_s", Json::num(m.prep_ns() as f64 * 1e-9)),
        ("requests", Json::num(m.device.num_requests as f64)),
        ("total_bytes", Json::num(m.device.total_bytes as f64)),
        ("achieved_bw_gbps", Json::num(m.device.achieved_bandwidth() / 1e9)),
        ("effective_gap_blocks", Json::num(m.effective_gap_blocks as f64)),
        (
            "shard_busy_ns",
            Json::arr(m.shards.busy_ns.iter().map(|&ns| Json::num(ns as f64)).collect()),
        ),
        ("shard_imbalance", Json::num(m.shard_imbalance())),
        // hex string, not a JSON number: f32 bit patterns survive exactly
        // (a float field would round away low mantissa bits and falsely
        // report bit-identical losses across shard counts)
        ("loss_bits", Json::str(format!("0x{:08x}", r.mean_loss.to_bits()))),
    ])
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();

    // ---- the dense sweep: real shard scaling, asserted -----------------
    println!("=== Figure 11: sharded dense sweep (AGNES) ===\n");
    let mut dense = Table::new(
        "fig11_dense_sharded",
        &["num_ssds", "prep_storage_s", "achieved_gbps", "util_pct", "imbalance"],
    );
    let mut dense_json: Vec<Json> = Vec::new();
    let mut dense_results: Vec<(u32, EpochResult)> = Vec::new();
    for ssds in SSDS {
        let mut c = dense_config(tiny);
        c.device.num_ssds = ssds;
        let spec = c.device.spec();
        let r = run_epoch_by_name("agnes", &c, &mut NullCompute)?;
        let m = &r.metrics;
        dense.row(vec![
            ssds.to_string(),
            secs(m.sample_io_ns + m.gather_io_ns),
            format!("{:.2}", m.device.achieved_bandwidth() / 1e9),
            format!("{:.1}", 100.0 * m.device.achieved_bandwidth() / spec.array_bandwidth()),
            format!("{:.2}", m.shard_imbalance()),
        ]);
        dense_json.push(shard_json(ssds, &r));
        dense_results.push((ssds, r));
    }
    dense.finish();

    // the acceptance gate CI relies on: adding a shard must not slow the
    // dense sweep down, and sharding must never change the training data
    let io = |r: &EpochResult| r.metrics.sample_io_ns + r.metrics.gather_io_ns;
    let (r1, r2) = (&dense_results[0].1, &dense_results[1].1);
    anyhow::ensure!(
        io(r2) <= io(r1),
        "2-shard dense sweep must not exceed 1-shard storage time: {} vs {}",
        io(r2),
        io(r1)
    );
    for (ssds, r) in &dense_results[1..] {
        anyhow::ensure!(
            r.mean_loss.to_bits() == r1.mean_loss.to_bits(),
            "{ssds}-shard loss diverged from single-device"
        );
        anyhow::ensure!(
            r.metrics.device.total_bytes == r1.metrics.device.total_bytes,
            "{ssds}-shard byte coverage diverged from single-device"
        );
    }
    println!(
        "\ndense sweep: 1 ssd {} -> 2 ssds {} -> 4 ssds {} (prep storage time)",
        secs(io(r1)),
        secs(io(r2)),
        secs(io(&dense_results[2].1)),
    );

    // ---- the per-dataset table of the paper's figure (skipped in the
    // tiny/CI smoke mode, which only runs the asserted dense sweep) -----
    let mut systems_json: Vec<Json> = Vec::new();
    if !tiny {
        let mut t = Table::new(
            "fig11_bandwidth",
            &["dataset", "system", "1_ssd", "2_ssd", "4_ssd", "util_4ssd_pct", "imbalance_4ssd"],
        );
        println!("\n=== Figure 11: achieved I/O bandwidth (GB/s) vs #SSDs ===\n");
        for &(ds, scale) in DATASETS {
            for system in ["agnes", "ginex"] {
                let mut cells = vec![ds.to_uppercase(), system.into()];
                let mut last_util = 0.0;
                let mut last_imbalance = 1.0;
                for ssds in SSDS {
                    let mut c = bench_config(ds, scale);
                    c.device.num_ssds = ssds;
                    let r = run_epoch_by_name(system, &c, &mut NullCompute)?;
                    let bw = r.metrics.device.achieved_bandwidth();
                    cells.push(format!("{:.2}", bw / 1e9));
                    last_util = bw / c.device.spec().array_bandwidth();
                    last_imbalance = r.metrics.shard_imbalance();
                    if ssds == 4 {
                        systems_json.push(Json::obj(vec![
                            ("system", Json::str(system)),
                            ("dataset", Json::str(ds)),
                            ("achieved_bw_gbps_4ssd", Json::num(bw / 1e9)),
                            ("util_4ssd", Json::num(last_util)),
                            ("shard_imbalance_4ssd", Json::num(last_imbalance)),
                        ]));
                    }
                }
                cells.push(format!("{:.1}", last_util * 100.0));
                cells.push(format!("{:.2}", last_imbalance));
                t.row(cells);
            }
        }
        t.finish();
    }

    // machine-readable perf record for the trajectory
    let report = Json::obj(vec![
        ("bench", Json::str("fig11_bandwidth")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("dense_sweep", Json::arr(dense_json)),
        ("systems", Json::arr(systems_json)),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_fig11.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_fig11.json");

    println!(
        "\nShape check vs paper: AGNES's achieved bandwidth scales with the \
         array — with real per-SSD queues the scaling now comes from shards \
         serving their own stripe regions concurrently (imbalance ~1 on the \
         dense sweep), while Ginex stays flat and low on its single queue \
         of latency-bound small I/Os."
    );
    Ok(())
}
