//! Figure 11 — maximum I/O bandwidth utilization of AGNES vs Ginex as the
//! SSD array grows (paper: AGNES reaches 17.3 GB/s on 4 drives; Ginex
//! cannot saturate even one).
//!
//! `cargo bench --bench fig11_bandwidth`

use agnes::coordinator::NullCompute;
use agnes::util::bench::{bench_config, run_epoch_by_name, Table};

const DATASETS: &[(&str, f64)] = &[("ig", 0.5), ("tw", 0.1), ("pa", 0.1), ("fr", 0.05), ("yh", 0.01)];

fn main() -> anyhow::Result<()> {
    println!("=== Figure 11: achieved I/O bandwidth (GB/s) vs #SSDs ===\n");
    let mut t = Table::new(
        "fig11_bandwidth",
        &["dataset", "system", "1_ssd", "2_ssd", "4_ssd", "util_4ssd_pct"],
    );
    for &(ds, scale) in DATASETS {
        for system in ["agnes", "ginex"] {
            let mut cells = vec![ds.to_uppercase(), system.into()];
            let mut last_util = 0.0;
            for ssds in [1u32, 2, 4] {
                let mut c = bench_config(ds, scale);
                c.device.num_ssds = ssds;
                let r = run_epoch_by_name(system, &c, &mut NullCompute)?;
                let bw = r.metrics.device.achieved_bandwidth();
                cells.push(format!("{:.2}", bw / 1e9));
                last_util = bw / (c.device.spec().array_bandwidth());
            }
            cells.push(format!("{:.1}", last_util * 100.0));
            t.row(cells);
        }
    }
    t.finish();
    println!(
        "\nShape check vs paper: AGNES's achieved bandwidth scales with the \
         array (multi-GB/s, up to ~17 GB/s at 4 drives in the paper); Ginex \
         stays flat and low (latency-bound small I/Os)."
    );
    Ok(())
}
