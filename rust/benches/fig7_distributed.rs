//! Figure 7 — distributed training: AGNES workers over partitioned SSD
//! arrays vs DistDGL (in-memory distributed, analytic cost model) on PA.
//!
//! Since `runtime::dist`, the AGNES side is a **real multi-worker
//! simulated epoch**: each worker runs a full services stack over its own
//! SSD array, trains the minibatches whose targets its partition owns,
//! and pays modeled halo-exchange + gradient all-reduce traffic over the
//! `NetModel` interconnect, with hyperbatch barriers ending each round at
//! the slowest worker. The DistDGL side intentionally stays the
//! closed-form model — its comm-bound scaling curve is the contrast.
//!
//! `cargo bench --bench fig7_distributed`
//!
//! Set `AGNES_FIG7_TINY=1` for the CI smoke configuration. Either way the
//! bench sweeps workers × shards, **asserts** that one worker is
//! bit-identical (loss bits + device counters) to the single-machine
//! path on every shard count, **asserts** that the modeled epoch
//! (storage + compute + comm) improves from 1 to 2 workers on the dense
//! leg, and emits `target/bench_results/BENCH_fig7.json` for the bench
//! gate.

use agnes::baselines::DistDglModel;
use agnes::config::AgnesConfig;
use agnes::coordinator::{ComputeBackend, EpochResult, ModeledCompute};
use agnes::runtime::dist::{DistEpochResult, DistRunner};
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};
use agnes::util::json::Json;

fn tiny_mode() -> bool {
    std::env::var("AGNES_FIG7_TINY").map(|v| v == "1").unwrap_or(false)
}

/// The fig7 workload. The tiny leg shrinks the minibatch so the target
/// stream still splits into enough minibatches that distributing them
/// across workers matters (one lone minibatch cannot speed up).
fn fig7_config(tiny: bool) -> AgnesConfig {
    if tiny {
        let mut c = bench_config("tiny", 1.0);
        c.train.minibatch_size = 20;
        c.train.target_fraction = 0.2;
        c
    } else {
        bench_config("pa", 0.1)
    }
}

/// One distributed leg: `workers` full stacks over a `ssds`-shard array,
/// each with its own modeled-GPU replica.
fn run_dist(
    base: &AgnesConfig,
    workers: usize,
    ssds: u32,
) -> anyhow::Result<(DistRunner, DistEpochResult)> {
    let mut c = base.clone();
    c.dist.workers = workers;
    c.device.num_ssds = ssds;
    let runner = DistRunner::open(c)?;
    let mut computes: Vec<Box<dyn ComputeBackend>> = (0..workers)
        .map(|_| Box::new(ModeledCompute::new(MODELED_COMPUTE_NS)) as Box<dyn ComputeBackend>)
        .collect();
    let d = runner.run_epoch(0, &mut computes)?;
    Ok((runner, d))
}

/// Per-machine comm time of a leg: workers communicate concurrently, so
/// the epoch pays the slowest worker's share (matches DistDGL's
/// per-machine `comm_secs`).
fn comm_ns(d: &DistEpochResult) -> u64 {
    d.workers.iter().map(|w| w.comm.comm_ns).max().unwrap_or(0)
}

fn dist_json(ssds: u32, workers: usize, partitioner: &str, d: &DistEpochResult) -> Json {
    let requests: u64 = d.workers.iter().map(|w| w.result.metrics.device.num_requests).sum();
    let total_bytes: u64 = d.workers.iter().map(|w| w.result.metrics.device.total_bytes).sum();
    let halo_bytes: u64 = d.workers.iter().map(|w| w.comm.halo_bytes).sum();
    let allreduce_bytes: u64 = d.workers.iter().map(|w| w.comm.allreduce_bytes).sum();
    Json::obj(vec![
        ("system", Json::str("agnes-dist")),
        ("num_ssds", Json::num(ssds as f64)),
        ("workers", Json::num(workers as f64)),
        ("partitioner", Json::str(partitioner)),
        // the deterministic barrier-synchronized span the gate pins
        ("epoch_modeled_s", Json::num(d.modeled_epoch_ns as f64 * 1e-9)),
        ("comm_s", Json::num(comm_ns(d) as f64 * 1e-9)),
        ("remote_fraction", Json::num(d.remote_fraction)),
        ("edge_cut", Json::num(d.edge_cut)),
        ("requests", Json::num(requests as f64)),
        ("total_bytes", Json::num(total_bytes as f64)),
        ("halo_bytes", Json::num(halo_bytes as f64)),
        ("allreduce_bytes", Json::num(allreduce_bytes as f64)),
        ("net_rpcs", Json::num(d.net.rpcs as f64)),
        // hex string so the f32 bit pattern is gated exactly
        ("loss_bits", Json::str(format!("0x{:08x}", d.mean_loss.to_bits()))),
    ])
}

fn main() -> anyhow::Result<()> {
    let tiny = tiny_mode();
    let base = fig7_config(tiny);
    let shards: &[u32] = if tiny { &[1, 2] } else { &[1, 4] };
    let worker_counts: &[usize] = if tiny { &[1, 2] } else { &[1, 2, 4] };

    println!("=== Figure 7: AGNES distributed workers vs DistDGL (PA, SAGE) ===\n");
    let mut t = Table::new(
        "fig7_distributed",
        &["system", "machines", "num_ssds", "epoch_s", "comm_s", "remote_frac", "edge_cut"],
    );

    // ---- the AGNES sweep: workers × shards, real simulated epochs ------
    let mut dist_json_rows: Vec<Json> = Vec::new();
    let mut legs: Vec<(u32, usize, DistEpochResult)> = Vec::new();
    let mut single: Vec<(u32, EpochResult)> = Vec::new();
    for &ssds in shards {
        // the single-machine reference for this shard count (also feeds
        // the DistDGL workload volume below)
        let mut c1 = base.clone();
        c1.device.num_ssds = ssds;
        let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
        let r = run_epoch_by_name("agnes", &c1, &mut compute)?;
        single.push((ssds, r));

        for &workers in worker_counts {
            let (runner, d) = run_dist(&base, workers, ssds)?;
            t.row(vec![
                "agnes".into(),
                workers.to_string(),
                ssds.to_string(),
                secs(d.modeled_epoch_ns),
                secs(comm_ns(&d)),
                format!("{:.3}", d.remote_fraction),
                format!("{:.3}", d.edge_cut),
            ]);
            dist_json_rows.push(dist_json(ssds, workers, &runner.partitioner().to_string(), &d));
            legs.push((ssds, workers, d));
        }
    }

    // ---- assert: one worker IS the single-machine path, bit for bit ----
    for &(ssds, workers, ref d) in &legs {
        if workers != 1 {
            continue;
        }
        let r = &single.iter().find(|(s, _)| *s == ssds).unwrap().1;
        let dm = &d.workers[0].result.metrics;
        anyhow::ensure!(
            d.mean_loss.to_bits() == r.mean_loss.to_bits(),
            "{ssds}-shard 1-worker loss diverged from single-machine: {:#010x} vs {:#010x}",
            d.mean_loss.to_bits(),
            r.mean_loss.to_bits()
        );
        anyhow::ensure!(
            dm.device.num_requests == r.metrics.device.num_requests
                && dm.device.total_bytes == r.metrics.device.total_bytes
                && dm.device.busy_ns == r.metrics.device.busy_ns
                && dm.minibatches == r.metrics.minibatches,
            "{ssds}-shard 1-worker device counters diverged from single-machine"
        );
        anyhow::ensure!(
            d.remote_fraction == 0.0 && d.net.bytes == 0,
            "one worker must pay no interconnect traffic"
        );
    }

    // ---- assert: distributing the epoch helps on the dense leg ---------
    let dense = *shards.last().unwrap();
    let modeled = |workers: usize| {
        legs.iter().find(|(s, w, _)| *s == dense && *w == workers).unwrap().2.modeled_epoch_ns
    };
    anyhow::ensure!(
        modeled(2) < modeled(1),
        "2 workers must beat 1 on the dense {dense}-shard leg: {} vs {}",
        secs(modeled(2)),
        secs(modeled(1))
    );
    for &(_, workers, ref d) in &legs {
        if workers > 1 {
            anyhow::ensure!(
                d.remote_fraction > 0.0 && d.remote_fraction < 1.0,
                "{workers} workers: remote fraction {} out of (0, 1)",
                d.remote_fraction
            );
            anyhow::ensure!(d.net.bytes > 0 && d.net.rpcs > 0, "{workers} workers moved no bytes");
        }
    }
    println!(
        "\ndense {dense}-shard leg: 1 worker {} -> 2 workers {} (modeled storage+compute+comm)",
        secs(modeled(1)),
        secs(modeled(2)),
    );

    // ---- the DistDGL contrast (analytic model, full mode only) ---------
    let mut distdgl_json: Vec<Json> = Vec::new();
    if !tiny {
        let r = &single[0].1;
        let num_minibatches = r.metrics.minibatches;
        let sampled_per_mb = r.metrics.sampled_nodes / num_minibatches.max(1);
        let spec =
            agnes::graph::datasets::DatasetSpec::preset("pa", 0.1, base.dataset.feature_dim)
                .unwrap();
        let g = spec.generate();
        for machines in [1usize, 2, 4] {
            let m = DistDglModel {
                num_machines: machines,
                compute_per_minibatch: MODELED_COMPUTE_NS as f64 * 1e-9,
                ..Default::default()
            };
            let e = m.epoch(&g, num_minibatches, sampled_per_mb, base.dataset.feature_dim);
            t.row(vec![
                "distdgl".into(),
                machines.to_string(),
                "-".into(),
                format!("{:.2}", e.total_secs),
                format!("{:.2}", e.comm_secs),
                format!("{:.3}", e.remote_fraction),
                "-".into(),
            ]);
            distdgl_json.push(Json::obj(vec![
                ("system", Json::str("distdgl")),
                ("machines", Json::num(machines as f64)),
                ("epoch_modeled_s", Json::num(e.total_secs)),
                ("comm_s", Json::num(e.comm_secs)),
                ("remote_fraction", Json::num(e.remote_fraction)),
            ]));
        }
    }
    t.finish();

    // machine-readable perf record for the trajectory
    let report = Json::obj(vec![
        ("bench", Json::str("fig7_distributed")),
        ("mode", Json::str(if tiny { "tiny" } else { "bench" })),
        ("dist", Json::arr(dist_json_rows)),
        ("distdgl", Json::arr(distdgl_json)),
    ]);
    std::fs::create_dir_all("target/bench_results")?;
    std::fs::write("target/bench_results/BENCH_fig7.json", report.to_string())?;
    println!("\n[json] target/bench_results/BENCH_fig7.json");

    println!(
        "\nShape check vs paper: AGNES's distributed epoch splits the storage \
         and compute work across workers while the interconnect charge stays \
         a small fraction of the saved time (halo features + ring all-reduce \
         over 100 Gb/s), so the modeled epoch shortens with workers; DistDGL's \
         analytic curve flattens as inter-machine communication takes over."
    );
    Ok(())
}
