//! Figure 7 — AGNES (single machine, storage-based) vs DistDGL (in-memory
//! distributed, analytic cost model) on PA: epoch time as the DistDGL
//! cluster grows 1 → 4 instances.
//!
//! `cargo bench --bench fig7_distributed`

use agnes::baselines::DistDglModel;
use agnes::coordinator::ModeledCompute;
use agnes::util::bench::{bench_config, run_epoch_by_name, secs, Table, MODELED_COMPUTE_NS};

fn main() -> anyhow::Result<()> {
    println!("=== Figure 7: AGNES vs DistDGL (PA, SAGE) ===\n");
    let config = bench_config("pa", 0.1);

    // measured: AGNES on this substrate
    let mut compute = ModeledCompute::new(MODELED_COMPUTE_NS);
    let r = run_epoch_by_name("agnes", &config, &mut compute)?;
    let agnes_total = r.metrics.sample_io_ns + r.metrics.gather_io_ns + compute.simulated_ns;
    let num_minibatches = r.metrics.minibatches;
    let sampled_per_mb = r.metrics.sampled_nodes / num_minibatches.max(1);

    // modeled: DistDGL with the same workload volume
    let spec =
        agnes::graph::datasets::DatasetSpec::preset("pa", 0.1, config.dataset.feature_dim).unwrap();
    let g = spec.generate();

    let mut t = Table::new(
        "fig7_distributed",
        &["system", "machines", "epoch_s", "comm_s", "remote_frac"],
    );
    t.row(vec!["agnes".into(), "1".into(), secs(agnes_total), "0".into(), "0".into()]);
    for machines in [1usize, 2, 4] {
        let m = DistDglModel {
            num_machines: machines,
            compute_per_minibatch: MODELED_COMPUTE_NS as f64 * 1e-9,
            ..Default::default()
        };
        let e = m.epoch(&g, num_minibatches, sampled_per_mb, config.dataset.feature_dim);
        t.row(vec![
            "distdgl".into(),
            machines.to_string(),
            format!("{:.2}", e.total_secs),
            format!("{:.2}", e.comm_secs),
            format!("{:.3}", e.remote_fraction),
        ]);
    }
    t.finish();
    println!(
        "\nShape check vs paper: AGNES on one machine is comparable to DistDGL \
         on ~2 instances — storage I/O (intra-machine) is cheaper than \
         inter-machine communication."
    );
    Ok(())
}
